//! Circuit simulation benchmark (Bauer et al. 2012): currents and voltages
//! over an unstructured circuit graph, partitioned into pieces with
//! private / shared node collections — the Legion benchmark whose expert
//! mapper the paper's search beats by 1.34x via ZCMEM->FBMEM flips on the
//! shared/ghost collections.
//!
//! Ghosting: each piece's `rp_ghost` argument is a *view* of the
//! neighbouring piece's `rp_shared` tile (RegionReq alias).  The expert
//! mapper places both in ZCMEM — node-shared host memory, so the exchange
//! costs nothing but every access crawls over PCIe.  The better mapper the
//! paper's search finds puts them in FBMEM: fast access, paid for with an
//! explicit inter-GPU copy whenever the neighbour's shared tile changed.
//!
//! Tasks (one launch point per piece, every step):
//!   calculate_new_currents (CNC): wire sweep reading node voltages
//!       (private + shared + ghost), updating wire currents.
//!   distribute_charge (DC): scatter charge from wires onto private +
//!       shared + ghost nodes (reductions on the shared collections).
//!   update_voltages (UV): node sweep refreshing voltages; rewrites the
//!       shared tiles, invalidating the neighbours' ghost copies.

use super::taskgraph::{Access, App, Launch, Metric, RegionDecl, RegionReq, TaskDecl};
use crate::machine::ProcKind;

/// Problem scale; default reproduces the paper-shaped workload.
#[derive(Debug, Clone, Copy)]
pub struct CircuitConfig {
    pub pieces: i64,
    /// Wires per piece.
    pub wires: u64,
    /// Private nodes per piece.
    pub private_nodes: u64,
    /// Shared nodes per piece (ghosted to the neighbour).
    pub shared_nodes: u64,
    pub steps: usize,
}

impl Default for CircuitConfig {
    fn default() -> Self {
        // 8 pieces (one per GPU on the 2x4 P100 machine); wire-dominated.
        CircuitConfig {
            pieces: 8,
            wires: 2 << 20,
            private_nodes: 1 << 18,
            shared_nodes: 1 << 13,
            steps: 10,
        }
    }
}

pub const WIRES: usize = 0;
pub const PRIVATE: usize = 1;
pub const SHARED: usize = 2;

pub fn circuit(cfg: CircuitConfig) -> App {
    let f = 4u64;
    let wire_fields = 8; // endpoints, inductance, resistance, current, ...
    let node_fields = 4; // voltage, charge, capacitance, leakage

    let regions = vec![
        RegionDecl {
            name: "rp_wires".into(),
            tile_bytes: cfg.wires * f * wire_fields as u64,
            fields: wire_fields,
            tiles: vec![cfg.pieces],
        },
        RegionDecl {
            name: "rp_private".into(),
            tile_bytes: cfg.private_nodes * f * node_fields as u64,
            fields: node_fields,
            tiles: vec![cfg.pieces],
        },
        RegionDecl {
            name: "rp_shared".into(),
            tile_bytes: cfg.shared_nodes * f * node_fields as u64,
            fields: node_fields,
            tiles: vec![cfg.pieces],
        },
    ];

    let all = vec![ProcKind::Gpu, ProcKind::Omp, ProcKind::Cpu];
    let tasks = vec![
        TaskDecl {
            name: "calculate_new_currents".into(),
            variants: all.clone(),
            flops_per_point: cfg.wires as f64 * 12.0,
            artifact: Some("circuit_cnc"),
            layout_reqs: vec![],
        },
        TaskDecl {
            name: "distribute_charge".into(),
            variants: all.clone(),
            flops_per_point: cfg.wires as f64 * 4.0,
            artifact: Some("circuit_dc"),
            layout_reqs: vec![],
        },
        TaskDecl {
            name: "update_voltages".into(),
            variants: all,
            flops_per_point: (cfg.private_nodes + cfg.shared_nodes) as f64 * 4.0,
            artifact: Some("circuit_uv"),
            layout_reqs: vec![],
        },
    ];

    let pieces = cfg.pieces;

    App::new(
        "circuit",
        tasks,
        regions,
        cfg.steps,
        Metric::StepsPerSecond,
        move |_step| {
            let ghost = move |p: &[i64]| vec![(p[0] + 1) % pieces];
            vec![
                // CNC: wires streamed once; node voltages read with fan-out
                // (each shared/ghost node feeds many boundary wires).
                Launch {
                    task: 0,
                    ispace: vec![pieces],
                    regions: vec![
                        RegionReq::own(WIRES, Access::ReadWrite, 1.0),
                        RegionReq::own(PRIVATE, Access::Read, 1.0),
                        RegionReq::own(SHARED, Access::Read, 2.0),
                        RegionReq::new(SHARED, Access::Read, 2.0, ghost)
                            .aliased("rp_ghost"),
                    ],
                },
                // DC: charge scatter; reductions on the shared collections
                Launch {
                    task: 1,
                    ispace: vec![pieces],
                    regions: vec![
                        RegionReq::own(WIRES, Access::Read, 0.5),
                        RegionReq::own(PRIVATE, Access::ReadWrite, 1.0),
                        RegionReq::own(SHARED, Access::Reduce, 2.0),
                        RegionReq::new(SHARED, Access::Reduce, 2.0, ghost)
                            .aliased("rp_ghost"),
                    ],
                },
                // UV: node sweep; rewriting shared invalidates ghosts
                Launch {
                    task: 2,
                    ispace: vec![pieces],
                    regions: vec![
                        RegionReq::own(PRIVATE, Access::ReadWrite, 1.0),
                        RegionReq::own(SHARED, Access::Write, 1.0),
                    ],
                },
            ]
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper_description() {
        let app = circuit(CircuitConfig::default());
        assert_eq!(app.tasks.len(), 3);
        assert_eq!(app.regions.len(), 3);
        let launches = app.launches(0);
        assert_eq!(launches.len(), 3);
        assert_eq!(app.data_arguments(), 10);
    }

    #[test]
    fn ghost_aliases_neighbour_shared() {
        let app = circuit(CircuitConfig::default());
        let launches = app.launches(0);
        let ghost = &launches[0].regions[3];
        assert_eq!(ghost.region, SHARED);
        assert_eq!(ghost.alias.as_deref(), Some("rp_ghost"));
        assert_eq!((ghost.tile_of)(&[7]), vec![0]); // wraps around
        assert_eq!((ghost.tile_of)(&[2]), vec![3]);
    }

    #[test]
    fn wires_dominate_bytes() {
        let app = circuit(CircuitConfig::default());
        assert!(app.regions[WIRES].tile_bytes > 20 * app.regions[SHARED].tile_bytes);
    }

    #[test]
    fn mapped_names_distinguish_views() {
        let app = circuit(CircuitConfig::default());
        let launches = app.launches(0);
        let cnc = &launches[0];
        assert_eq!(cnc.regions[2].mapped_name(&app.regions), "rp_shared");
        assert_eq!(cnc.regions[3].mapped_name(&app.regions), "rp_ghost");
    }
}
