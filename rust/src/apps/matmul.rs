//! The six distributed matrix-multiplication algorithms of Section 5.3:
//! Cannon's, SUMMA, PUMMA, Johnson's 3D, Solomonik's 2.5D, and COSMA.
//!
//! All compute C = A @ B for N x N f32 matrices, but decompose the
//! iteration space differently — which makes *index mapping* (which GPU
//! runs which tile-task) the performance-critical mapper decision: it
//! determines how many A/B tiles each GPU must fetch from remote
//! framebuffers across the algorithm's steps.
//!
//! Tile requirements per algorithm (grid p=4 for 2D, q=2 for 3D, N=8192):
//!   Cannon  step s, task (i,j):  A(i, (i+j+s)%p), B((i+j+s)%p, j)
//!   SUMMA   step k, task (i,j):  A(i, k),         B(k, j)
//!   PUMMA   step k, task (i,j):  A(i, (j+k)%p),   B((i+k)%p, j)
//!   Johnson single step, task (i,j,k): A(i,k), B(k,j) -> Cpart(i,j,k),
//!           then reduce_c over (i,j) combines the k partials.
//!   Solomonik steps s in 0..p/c, task (i,j,l): A(i, l*S+s), B(l*S+s, j)
//!           -> Cpart(i,j,l), then reduce_c combines the c layers.
//!   COSMA   single step, task (i,j) on a (4, 2) grid: row-panel A(i),
//!           col-panel B(j) -> C(i,j)  (comm-optimal panel decomposition).

use super::taskgraph::{
    Access, App, InitialDist, Launch, LayoutReq, Metric, RegionDecl, RegionReq,
    TaskDecl,
};
use crate::machine::ProcKind;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    Cannon,
    Summa,
    Pumma,
    Johnson,
    Solomonik,
    Cosma,
}

impl Algorithm {
    pub const ALL: [Algorithm; 6] = [
        Algorithm::Cannon,
        Algorithm::Summa,
        Algorithm::Pumma,
        Algorithm::Johnson,
        Algorithm::Solomonik,
        Algorithm::Cosma,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Cannon => "cannon",
            Algorithm::Summa => "summa",
            Algorithm::Pumma => "pumma",
            Algorithm::Johnson => "johnson",
            Algorithm::Solomonik => "solomonik",
            Algorithm::Cosma => "cosma",
        }
    }

    pub fn parse(s: &str) -> Option<Algorithm> {
        Self::ALL.into_iter().find(|a| a.name() == s)
    }
}

#[derive(Debug, Clone, Copy)]
pub struct MatmulConfig {
    /// Matrix side length (elements).
    pub n: u64,
    /// 2D algorithms use a p x p tile grid.
    pub p: i64,
    /// 3D algorithms use a q x q x q grid.
    pub q: i64,
}

impl Default for MatmulConfig {
    fn default() -> Self {
        MatmulConfig { n: 8192, p: 4, q: 2 }
    }
}

fn region(name: &str, tile_bytes: u64, tiles: Vec<i64>) -> RegionDecl {
    RegionDecl { name: name.into(), tile_bytes, fields: 1, tiles }
}

fn dgemm_task(name: &str, flops: f64) -> TaskDecl {
    TaskDecl {
        name: name.into(),
        variants: vec![ProcKind::Gpu, ProcKind::Omp, ProcKind::Cpu],
        flops_per_point: flops,
        artifact: Some("gemm_tile_step"),
        // the CPU/OMP variants call BLAS DGEMM compiled for Fortran order:
        // mapping them with C_order raises "DGEMM parameter number 8 had
        // an illegal value"
        layout_reqs: vec![
            (ProcKind::Cpu, LayoutReq { requires_soa: false, requires_f_order: true }),
            (ProcKind::Omp, LayoutReq { requires_soa: false, requires_f_order: true }),
        ],
    }
}

/// Build the App for one algorithm.
pub fn matmul(algo: Algorithm, cfg: MatmulConfig) -> App {
    let n = cfg.n;
    let total_flops = 2.0 * (n as f64).powi(3);
    let metric = Metric::Gflops { total_flops };
    match algo {
        Algorithm::Cannon | Algorithm::Summa | Algorithm::Pumma => {
            let p = cfg.p;
            let tb = (n / p as u64) * (n / p as u64) * 4;
            let tile_flops = 2.0 * ((n / p as u64) as f64).powi(3);
            let regions = vec![
                region("mat_a", tb, vec![p, p]),
                region("mat_b", tb, vec![p, p]),
                region("mat_c", tb, vec![p, p]),
            ];
            let tasks = vec![dgemm_task("dgemm", tile_flops)];
            App::new(
                algo.name(),
                tasks,
                regions,
                p as usize, // p k-steps complete the multiply
                metric,
                move |step| {
                    let s = step as i64;
                    let (a_of, b_of): (
                        Box<dyn Fn(&[i64]) -> Vec<i64> + Send + Sync>,
                        Box<dyn Fn(&[i64]) -> Vec<i64> + Send + Sync>,
                    ) = match algo {
                        Algorithm::Cannon => (
                            Box::new(move |pt: &[i64]| {
                                vec![pt[0], (pt[0] + pt[1] + s) % p]
                            }),
                            Box::new(move |pt: &[i64]| {
                                vec![(pt[0] + pt[1] + s) % p, pt[1]]
                            }),
                        ),
                        Algorithm::Summa => (
                            Box::new(move |pt: &[i64]| vec![pt[0], s % p]),
                            Box::new(move |pt: &[i64]| vec![s % p, pt[1]]),
                        ),
                        _ => (
                            Box::new(move |pt: &[i64]| {
                                vec![pt[0], (pt[1] + s) % p]
                            }),
                            Box::new(move |pt: &[i64]| {
                                vec![(pt[0] + s) % p, pt[1]]
                            }),
                        ),
                    };
                    vec![Launch {
                        task: 0,
                        ispace: vec![p, p],
                        regions: vec![
                            RegionReq {
                                region: 0,
                                access: Access::Read,
                                reuse: 1.0,
                                tile_of: a_of,
                                alias: None,
                                bytes_override: None,
                            },
                            RegionReq {
                                region: 1,
                                access: Access::Read,
                                reuse: 1.0,
                                tile_of: b_of,
                                alias: None,
                                bytes_override: None,
                            },
                            RegionReq::own(2, Access::ReadWrite, 1.0),
                        ],
                    }]
                },
            )
            .with_initial_dist(InitialDist::BlockOverGpus)
        }

        Algorithm::Johnson => {
            let q = cfg.q;
            let t = n / q as u64;
            let tb = t * t * 4;
            let tile_flops = 2.0 * (t as f64).powi(3);
            let regions = vec![
                region("mat_a", tb, vec![q, q]),
                region("mat_b", tb, vec![q, q]),
                region("mat_c_part", tb, vec![q, q, q]),
                region("mat_c", tb, vec![q, q]),
            ];
            let tasks = vec![
                dgemm_task("dgemm", tile_flops),
                TaskDecl {
                    name: "reduce_c".into(),
                    variants: vec![ProcKind::Gpu, ProcKind::Cpu],
                    flops_per_point: (t * t) as f64 * q as f64,
                    artifact: None,
                    layout_reqs: vec![],
                },
            ];
            App::new(
                algo.name(),
                tasks,
                regions,
                1,
                metric,
                move |_step| {
                    let mut launches = vec![Launch {
                        task: 0,
                        ispace: vec![q, q, q],
                        regions: vec![
                            RegionReq::new(0, Access::Read, 1.0, |pt: &[i64]| {
                                vec![pt[0], pt[2]]
                            }),
                            RegionReq::new(1, Access::Read, 1.0, |pt: &[i64]| {
                                vec![pt[2], pt[1]]
                            }),
                            RegionReq::own(2, Access::Write, 1.0),
                        ],
                    }];
                    // reduction: C(i,j) <- sum_k Cpart(i,j,k)
                    let mut reduce_regions: Vec<RegionReq> = (0..q)
                        .map(|k| {
                            RegionReq::new(2, Access::Read, 1.0, move |pt: &[i64]| {
                                vec![pt[0], pt[1], k]
                            })
                        })
                        .collect();
                    reduce_regions.push(RegionReq::own(3, Access::Write, 1.0));
                    launches.push(Launch {
                        task: 1,
                        ispace: vec![q, q],
                        regions: reduce_regions,
                    });
                    launches
                },
            )
            .with_initial_dist(InitialDist::BlockOverGpus)
        }

        Algorithm::Solomonik => {
            // 2.5D: c = q replication layers; k split into p = q*c chunks,
            // S = p / c sequential steps per layer.
            let q = cfg.q;
            let c = cfg.q;
            let steps = 2usize; // p/c with p = 4, c = 2
            let kchunks = steps as i64 * c;
            let tm = n / q as u64; // C tile side
            let tk = n / kchunks as u64; // k-chunk depth
            let ab_bytes = tm * tk * 4;
            let c_bytes = tm * tm * 4;
            let tile_flops = 2.0 * tm as f64 * tm as f64 * tk as f64;
            let regions = vec![
                region("mat_a", ab_bytes, vec![q, kchunks]),
                region("mat_b", ab_bytes, vec![kchunks, q]),
                region("mat_c_part", c_bytes, vec![q, q, c]),
                region("mat_c", c_bytes, vec![q, q]),
            ];
            let tasks = vec![
                dgemm_task("dgemm", tile_flops),
                TaskDecl {
                    name: "reduce_c".into(),
                    variants: vec![ProcKind::Gpu, ProcKind::Cpu],
                    flops_per_point: (tm * tm) as f64 * c as f64,
                    artifact: None,
                    layout_reqs: vec![],
                },
            ];
            App::new(
                algo.name(),
                tasks,
                regions,
                steps,
                metric,
                move |step| {
                    let s = step as i64;
                    let last = step + 1 == steps;
                    let mut launches = vec![Launch {
                        task: 0,
                        ispace: vec![q, q, c],
                        regions: vec![
                            RegionReq::new(0, Access::Read, 1.0, move |pt: &[i64]| {
                                vec![pt[0], pt[2] * 2 + s]
                            }),
                            RegionReq::new(1, Access::Read, 1.0, move |pt: &[i64]| {
                                vec![pt[2] * 2 + s, pt[1]]
                            }),
                            RegionReq::own(2, Access::ReadWrite, 1.0),
                        ],
                    }];
                    if last {
                        let mut rr: Vec<RegionReq> = (0..c)
                            .map(|l| {
                                RegionReq::new(2, Access::Read, 1.0, move |pt: &[i64]| {
                                    vec![pt[0], pt[1], l]
                                })
                            })
                            .collect();
                        rr.push(RegionReq::own(3, Access::Write, 1.0));
                        launches.push(Launch { task: 1, ispace: vec![q, q], regions: rr });
                    }
                    launches
                },
            )
            .with_initial_dist(InitialDist::BlockOverGpus)
        }

        Algorithm::Cosma => {
            // comm-optimal panel split for 8 processors: 4 row-panels of A
            // times 2 col-panels of B, one task per C panel-block.
            let (pm, pn) = (4i64, 2i64);
            let a_bytes = (n / pm as u64) * n * 4;
            let b_bytes = n * (n / pn as u64) * 4;
            let c_bytes = (n / pm as u64) * (n / pn as u64) * 4;
            let tile_flops = 2.0 * (n / pm as u64) as f64 * (n / pn as u64) as f64 * n as f64;
            let regions = vec![
                region("mat_a", a_bytes, vec![pm, 1]),
                region("mat_b", b_bytes, vec![1, pn]),
                region("mat_c", c_bytes, vec![pm, pn]),
            ];
            let tasks = vec![dgemm_task("dgemm", tile_flops)];
            App::new(
                algo.name(),
                tasks,
                regions,
                1,
                metric,
                move |_step| {
                    vec![Launch {
                        task: 0,
                        ispace: vec![pm, pn],
                        regions: vec![
                            RegionReq::new(0, Access::Read, 1.0, |pt: &[i64]| {
                                vec![pt[0], 0]
                            }),
                            RegionReq::new(1, Access::Read, 1.0, |pt: &[i64]| {
                                vec![0, pt[1]]
                            }),
                            RegionReq::own(2, Access::Write, 1.0),
                        ],
                    }]
                },
            )
            .with_initial_dist(InitialDist::BlockOverGpus)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_algorithms_build() {
        for algo in Algorithm::ALL {
            let app = matmul(algo, MatmulConfig::default());
            assert!(!app.launches(0).is_empty(), "{}", app.name);
            assert_eq!(app.initial_dist, InitialDist::BlockOverGpus);
        }
    }

    #[test]
    fn flops_sum_to_2n3() {
        // the dgemm launches of every algorithm perform exactly 2N^3 flops
        for algo in Algorithm::ALL {
            let app = matmul(algo, MatmulConfig::default());
            let n = 8192f64;
            let dgemm = app.task_index("dgemm").unwrap();
            let mut flops = 0.0;
            for s in 0..app.steps {
                for l in app.launches(s) {
                    if l.task == dgemm {
                        flops += app.tasks[l.task].flops_per_point * l.num_points() as f64;
                    }
                }
            }
            let expect = 2.0 * n.powi(3);
            assert!(
                (flops - expect).abs() / expect < 1e-9,
                "{}: {flops} vs {expect}",
                algo.name()
            );
        }
    }

    #[test]
    fn cannon_systolic_shift() {
        let app = matmul(Algorithm::Cannon, MatmulConfig::default());
        let l0 = app.launches(0);
        let l1 = app.launches(1);
        let a0 = (l0[0].regions[0].tile_of)(&[1, 2]);
        let a1 = (l1[0].regions[0].tile_of)(&[1, 2]);
        assert_eq!(a0, vec![1, 3]); // (1+2+0) % 4
        assert_eq!(a1, vec![1, 0]); // (1+2+1) % 4
    }

    #[test]
    fn summa_broadcasts_k_panel() {
        let app = matmul(Algorithm::Summa, MatmulConfig::default());
        let l2 = app.launches(2);
        // every task reads the same A column k=2
        assert_eq!((l2[0].regions[0].tile_of)(&[0, 0]), vec![0, 2]);
        assert_eq!((l2[0].regions[0].tile_of)(&[3, 1]), vec![3, 2]);
        assert_eq!((l2[0].regions[1].tile_of)(&[3, 1]), vec![2, 1]);
    }

    #[test]
    fn johnson_reduction_reads_all_layers() {
        let app = matmul(Algorithm::Johnson, MatmulConfig::default());
        let launches = app.launches(0);
        assert_eq!(launches.len(), 2);
        let reduce = &launches[1];
        assert_eq!(reduce.regions.len(), 3); // q=2 partials + output
        assert_eq!((reduce.regions[0].tile_of)(&[1, 0]), vec![1, 0, 0]);
        assert_eq!((reduce.regions[1].tile_of)(&[1, 0]), vec![1, 0, 1]);
    }

    #[test]
    fn solomonik_reduces_only_at_last_step() {
        let app = matmul(Algorithm::Solomonik, MatmulConfig::default());
        assert_eq!(app.launches(0).len(), 1);
        assert_eq!(app.launches(1).len(), 2);
    }

    #[test]
    fn cpu_variant_requires_fortran_order() {
        let app = matmul(Algorithm::Summa, MatmulConfig::default());
        let dgemm = &app.tasks[0];
        assert!(dgemm.layout_req(ProcKind::Cpu).requires_f_order);
        assert!(!dgemm.layout_req(ProcKind::Gpu).requires_f_order);
    }

    #[test]
    fn algorithm_name_roundtrip() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::parse(a.name()), Some(a));
        }
        assert_eq!(Algorithm::parse("nope"), None);
    }
}
