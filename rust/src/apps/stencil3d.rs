//! 3D halo-exchange stencil with *split interior/boundary launches* —
//! the overlap-aware stress scenario for the out-of-order engine, and
//! the scale knob behind the `sched_scale` bench (it grows cleanly past
//! 10^5 point tasks).
//!
//! Classic communication/computation-overlap decomposition: each
//! timestep runs three launches over a `px x py x pz` tile grid,
//!
//!   interior: reads only the tile's own cells, writes the `core`
//!             result — pure local compute, no halo traffic;
//!   boundary: reads the tile's thin shell plus six neighbour *face*
//!             strips (halo views of the neighbours' `grid` tiles, torus
//!             wrap), writes the `shell` result — all of the step's
//!             communication, little compute;
//!   update:   folds `core` + `shell` back into the `grid` tile.
//!
//! Under inferred dependencies a tile's `interior` and `boundary` both
//! depend only on the previous step's `update`s, so boundary halo
//! transfers (NIC-serialized at node frontiers) overlap interior compute
//! and the steps pipeline; the bulk-synchronous barrier instead stalls
//! every processor on the slowest frontier transfer, launch after
//! launch.  That gap is exactly what `OutOfOrder` vs `Serialized`
//! measures on this app.

use super::taskgraph::{Access, App, Launch, Metric, RegionDecl, RegionReq, TaskDecl};
use crate::machine::ProcKind;

#[derive(Debug, Clone, Copy)]
pub struct Stencil3dConfig {
    /// Tile grid extents (px x py x pz tiles).
    pub px: i64,
    pub py: i64,
    pub pz: i64,
    /// Block side length: each tile is `block^3` f32 cells.
    pub block: u64,
    pub steps: usize,
}

impl Default for Stencil3dConfig {
    fn default() -> Self {
        // 16 tiles over 8 GPUs, 128^3 cells (8 MB) per tile
        Stencil3dConfig { px: 4, py: 2, pz: 2, block: 128, steps: 10 }
    }
}

impl Stencil3dConfig {
    /// Smallest power-of-two growth of the default tile grid whose task
    /// graph has at least `n` point tasks (3 launches per tile per
    /// step) — the scale knob of `benches/sched_scale.rs` and the
    /// large-graph parity tests.
    pub fn with_min_point_tasks(n: usize) -> Self {
        let mut cfg = Stencil3dConfig::default();
        let mut axis = 0usize;
        while cfg.point_tasks() < n {
            match axis % 3 {
                0 => cfg.px *= 2,
                1 => cfg.py *= 2,
                _ => cfg.pz *= 2,
            }
            axis += 1;
        }
        cfg
    }

    /// Point tasks in the flattened task graph.
    pub fn point_tasks(&self) -> usize {
        3 * (self.px * self.py * self.pz) as usize * self.steps
    }
}

pub const GRID: usize = 0;
pub const CORE: usize = 1;
pub const SHELL: usize = 2;

pub fn stencil3d(cfg: Stencil3dConfig) -> App {
    let f = 4u64; // f32 cells
    let block_bytes = cfg.block * cfg.block * cfg.block * f;
    // one halo face strip / the tile's own six-face shell
    let face_bytes = cfg.block * cfg.block * f;
    let shell_bytes = 6 * face_bytes;

    let tiles = vec![cfg.px, cfg.py, cfg.pz];
    let regions = vec![
        RegionDecl {
            name: "grid".into(),
            tile_bytes: block_bytes,
            fields: 1,
            tiles: tiles.clone(),
        },
        RegionDecl {
            name: "core".into(),
            tile_bytes: block_bytes,
            fields: 1,
            tiles: tiles.clone(),
        },
        RegionDecl {
            name: "shell".into(),
            tile_bytes: shell_bytes,
            fields: 1,
            tiles,
        },
    ];

    let b3 = (cfg.block * cfg.block * cfg.block) as f64;
    let b2 = (cfg.block * cfg.block) as f64;
    let tasks = vec![
        TaskDecl {
            name: "interior".into(),
            variants: vec![ProcKind::Gpu, ProcKind::Omp, ProcKind::Cpu],
            // 27-point stencil over the tile interior
            flops_per_point: b3 * 27.0,
            artifact: None,
            layout_reqs: vec![],
        },
        TaskDecl {
            name: "boundary".into(),
            variants: vec![ProcKind::Gpu, ProcKind::Omp, ProcKind::Cpu],
            flops_per_point: 6.0 * b2 * 27.0,
            artifact: None,
            layout_reqs: vec![],
        },
        TaskDecl {
            name: "update".into(),
            variants: vec![ProcKind::Gpu, ProcKind::Omp, ProcKind::Cpu],
            flops_per_point: b3 * 2.0,
            artifact: None,
            layout_reqs: vec![],
        },
    ];

    let (px, py, pz) = (cfg.px, cfg.py, cfg.pz);
    App::new(
        "stencil3d",
        tasks,
        regions,
        cfg.steps,
        Metric::StepsPerSecond,
        move |_step| {
            let xp = move |p: &[i64]| vec![(p[0] + 1) % px, p[1], p[2]];
            let xm = move |p: &[i64]| vec![(p[0] - 1).rem_euclid(px), p[1], p[2]];
            let yp = move |p: &[i64]| vec![p[0], (p[1] + 1) % py, p[2]];
            let ym = move |p: &[i64]| vec![p[0], (p[1] - 1).rem_euclid(py), p[2]];
            let zp = move |p: &[i64]| vec![p[0], p[1], (p[2] + 1) % pz];
            let zm = move |p: &[i64]| vec![p[0], p[1], (p[2] - 1).rem_euclid(pz)];
            let ispace = vec![px, py, pz];
            vec![
                // interior: own cells only — overlappable local compute
                Launch {
                    task: 0,
                    ispace: ispace.clone(),
                    regions: vec![
                        RegionReq::own(GRID, Access::Read, 2.0),
                        RegionReq::own(CORE, Access::Write, 1.0),
                    ],
                },
                // boundary: thin own shell + six neighbour faces (halo
                // views of `grid`, wrapping like a torus)
                Launch {
                    task: 1,
                    ispace: ispace.clone(),
                    regions: vec![
                        RegionReq::own(GRID, Access::Read, 2.0)
                            .aliased("shell_src")
                            .bytes(shell_bytes),
                        RegionReq::new(GRID, Access::Read, 2.0, xp)
                            .aliased("halo_xp")
                            .bytes(face_bytes),
                        RegionReq::new(GRID, Access::Read, 2.0, xm)
                            .aliased("halo_xm")
                            .bytes(face_bytes),
                        RegionReq::new(GRID, Access::Read, 2.0, yp)
                            .aliased("halo_yp")
                            .bytes(face_bytes),
                        RegionReq::new(GRID, Access::Read, 2.0, ym)
                            .aliased("halo_ym")
                            .bytes(face_bytes),
                        RegionReq::new(GRID, Access::Read, 2.0, zp)
                            .aliased("halo_zp")
                            .bytes(face_bytes),
                        RegionReq::new(GRID, Access::Read, 2.0, zm)
                            .aliased("halo_zm")
                            .bytes(face_bytes),
                        RegionReq::own(SHELL, Access::Write, 1.0),
                    ],
                },
                // update: fold core + shell back into the state tile
                Launch {
                    task: 2,
                    ispace,
                    regions: vec![
                        RegionReq::own(CORE, Access::Read, 1.0),
                        RegionReq::own(SHELL, Access::Read, 1.0),
                        RegionReq::own(GRID, Access::ReadWrite, 1.0),
                    ],
                },
            ]
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn three_split_launches_per_step() {
        let app = stencil3d(Stencil3dConfig::default());
        let ls = app.launches(0);
        assert_eq!(ls.len(), 3);
        assert_eq!(app.tasks.len(), 3);
        for l in &ls {
            assert_eq!(l.num_points(), 16); // 4 x 2 x 2 tiles
        }
        assert_eq!(ls[1].regions.len(), 8, "shell + 6 halos + output");
        assert_eq!(Stencil3dConfig::default().point_tasks(), 3 * 16 * 10);
    }

    #[test]
    fn halos_wrap_torus_and_are_thin() {
        let app = stencil3d(Stencil3dConfig::default());
        let l = app.launches(0);
        let xm = &l[1].regions[2]; // halo_xm
        assert_eq!((xm.tile_of)(&[0, 1, 0]), vec![3, 1, 0]);
        let zp = &l[1].regions[5]; // halo_zp
        assert_eq!((zp.tile_of)(&[1, 0, 1]), vec![1, 0, 0]);
        assert!(
            xm.touched_bytes(&app.regions) < app.regions[GRID].tile_bytes / 100,
            "halo faces must be thin strips"
        );
    }

    #[test]
    fn halo_alias_names_visible_to_mapper() {
        let app = stencil3d(Stencil3dConfig::default());
        let l = app.launches(0);
        let names: Vec<&str> =
            l[1].regions.iter().map(|r| r.mapped_name(&app.regions)).collect();
        for want in ["shell_src", "halo_xp", "halo_zm", "shell"] {
            assert!(names.contains(&want), "missing region arg name {want}");
        }
    }

    #[test]
    fn scale_knob_reaches_target_sizes() {
        for n in [1_000, 10_000, 50_000, 100_000] {
            let cfg = Stencil3dConfig::with_min_point_tasks(n);
            assert!(cfg.point_tasks() >= n);
            assert!(cfg.point_tasks() < 8 * n, "overshoot at {n}");
            let app = stencil3d(cfg);
            assert_eq!(app.launches(0).len(), 3);
        }
    }
}
