//! Pennant benchmark (Ferenbaugh 2015): unstructured-mesh Lagrangian
//! staggered-grid hydrodynamics for compressible flow, partitioned into
//! mesh pieces with private / master (shared) / slave (ghost) point
//! collections — the third scientific application of Section 5.2.
//!
//! `points_slave` arguments are views of the neighbouring piece's
//! `points_master` tile (same ghosting mechanism as circuit).  The GPU
//! variant of the corner-force kernel was compiled for SOA point
//! instances: forcing AOS on it reproduces the paper's "stride does not
//! match expected value" execution error.
//!
//! Task pipeline per cycle (the four dominant kernels):
//!   adv_pos_half, calc_crnr_force, sum_crnr_force, calc_eos_work.

use super::taskgraph::{
    Access, App, Launch, LayoutReq, Metric, RegionDecl, RegionReq, TaskDecl,
};
use crate::machine::ProcKind;

#[derive(Debug, Clone, Copy)]
pub struct PennantConfig {
    pub pieces: i64,
    pub zones: u64,
    pub points_private: u64,
    pub points_shared: u64,
    pub steps: usize,
}

impl Default for PennantConfig {
    fn default() -> Self {
        PennantConfig {
            pieces: 8,
            zones: 1 << 19,
            points_private: 1 << 19,
            points_shared: 1 << 10,
            steps: 10,
        }
    }
}

pub const ZONES: usize = 0;
pub const SIDES: usize = 1;
pub const PPRIV: usize = 2;
pub const PMASTER: usize = 3;

pub fn pennant(cfg: PennantConfig) -> App {
    let f = 4u64;
    let zone_fields = 6; // rho, e, p, vol, mass, work
    let side_fields = 4; // corner force x/y, side area, mass flux
    let point_fields = 6; // pos x/y, vel x/y, force x/y

    let regions = vec![
        RegionDecl {
            name: "zones".into(),
            tile_bytes: cfg.zones * f * zone_fields as u64,
            fields: zone_fields,
            tiles: vec![cfg.pieces],
        },
        RegionDecl {
            name: "sides".into(),
            tile_bytes: cfg.zones * 4 * f * side_fields as u64, // ~4 sides/zone
            fields: side_fields,
            tiles: vec![cfg.pieces],
        },
        RegionDecl {
            name: "points_private".into(),
            tile_bytes: cfg.points_private * f * point_fields as u64,
            fields: point_fields,
            tiles: vec![cfg.pieces],
        },
        RegionDecl {
            name: "points_master".into(),
            tile_bytes: cfg.points_shared * f * point_fields as u64,
            fields: point_fields,
            tiles: vec![cfg.pieces],
        },
    ];

    let all = vec![ProcKind::Gpu, ProcKind::Omp, ProcKind::Cpu];
    // GPU point kernels were compiled against SOA instances
    let gpu_soa = vec![(
        ProcKind::Gpu,
        LayoutReq { requires_soa: true, requires_f_order: false },
    )];
    let tasks = vec![
        TaskDecl {
            name: "adv_pos_half".into(),
            variants: all.clone(),
            flops_per_point: (cfg.points_private + cfg.points_shared) as f64 * 8.0,
            artifact: None,
            layout_reqs: gpu_soa.clone(),
        },
        TaskDecl {
            name: "calc_crnr_force".into(),
            variants: all.clone(),
            flops_per_point: cfg.zones as f64 * 4.0 * 22.0,
            artifact: None,
            layout_reqs: gpu_soa.clone(),
        },
        TaskDecl {
            name: "sum_crnr_force".into(),
            variants: all.clone(),
            flops_per_point: (cfg.points_private + 2 * cfg.points_shared) as f64 * 6.0,
            artifact: None,
            layout_reqs: gpu_soa,
        },
        TaskDecl {
            name: "calc_eos_work".into(),
            variants: all,
            flops_per_point: cfg.zones as f64 * 14.0,
            artifact: Some("pennant_hydro"),
            layout_reqs: vec![],
        },
    ];

    let pieces = cfg.pieces;
    App::new(
        "pennant",
        tasks,
        regions,
        cfg.steps,
        Metric::StepsPerSecond,
        move |_step| {
            let slave = move |p: &[i64]| vec![(p[0] + 1) % pieces];
            vec![
                Launch {
                    task: 0,
                    ispace: vec![pieces],
                    regions: vec![
                        RegionReq::own(PPRIV, Access::ReadWrite, 1.0),
                        RegionReq::own(PMASTER, Access::ReadWrite, 1.0),
                    ],
                },
                Launch {
                    task: 1,
                    ispace: vec![pieces],
                    regions: vec![
                        RegionReq::own(ZONES, Access::Read, 1.0),
                        RegionReq::own(SIDES, Access::ReadWrite, 1.0),
                        RegionReq::own(PPRIV, Access::Read, 2.0),
                        RegionReq::new(PMASTER, Access::Read, 2.0, slave)
                            .aliased("points_slave"),
                    ],
                },
                Launch {
                    task: 2,
                    ispace: vec![pieces],
                    regions: vec![
                        RegionReq::own(SIDES, Access::Read, 1.0),
                        RegionReq::own(PPRIV, Access::ReadWrite, 1.0),
                        RegionReq::own(PMASTER, Access::Reduce, 2.0),
                        RegionReq::new(PMASTER, Access::Reduce, 2.0, slave)
                            .aliased("points_slave"),
                    ],
                },
                Launch {
                    task: 3,
                    ispace: vec![pieces],
                    regions: vec![
                        RegionReq::own(ZONES, Access::ReadWrite, 1.0),
                        RegionReq::own(SIDES, Access::Read, 0.5),
                    ],
                },
            ]
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_task_pipeline() {
        let app = pennant(PennantConfig::default());
        assert_eq!(app.tasks.len(), 4);
        assert_eq!(app.launches(0).len(), 4);
        assert_eq!(app.regions.len(), 4);
        assert_eq!(app.data_arguments(), 12);
    }

    #[test]
    fn slave_points_alias_neighbour_master() {
        let app = pennant(PennantConfig::default());
        let l = app.launches(0);
        let slave = &l[1].regions[3];
        assert_eq!(slave.region, PMASTER);
        assert_eq!(slave.mapped_name(&app.regions), "points_slave");
        assert_eq!((slave.tile_of)(&[7]), vec![0]);
    }

    #[test]
    fn gpu_kernels_require_soa() {
        let app = pennant(PennantConfig::default());
        assert!(app.tasks[1].layout_req(ProcKind::Gpu).requires_soa);
        assert!(!app.tasks[1].layout_req(ProcKind::Cpu).requires_soa);
        assert!(!app.tasks[3].layout_req(ProcKind::Gpu).requires_soa);
    }

    #[test]
    fn zone_work_dominates_flops() {
        let app = pennant(PennantConfig::default());
        assert!(app.tasks[1].flops_per_point > app.tasks[0].flops_per_point);
    }
}
