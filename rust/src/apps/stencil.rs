//! PRK Stencil benchmark (Van der Wijngaart & Mattson 2014): 2D star
//! stencil over a block-partitioned grid — the paper's smallest search
//! space ("2 tasks and 12 data arguments", 2^38 configurations).
//!
//! Halos are views: the four `halo_*` arguments of the stencil task alias
//! the neighbouring blocks' `grid_in` tiles but touch only one edge strip
//! (bytes_override), so placing them in ZCMEM vs FBMEM trades PCIe-speed
//! access against explicit strip copies, exactly like circuit's ghosts.
//!
//! Tasks per step:
//!   stencil:   in block + 4 halo strips + weights -> out block (7 args).
//!   increment: in += out + coefficient arrays (5 args).

use super::taskgraph::{Access, App, Launch, Metric, RegionDecl, RegionReq, TaskDecl};
use crate::machine::ProcKind;

#[derive(Debug, Clone, Copy)]
pub struct StencilConfig {
    /// Piece grid is px x py.
    pub px: i64,
    pub py: i64,
    /// Block side length (elements).
    pub block: u64,
    pub steps: usize,
}

impl Default for StencilConfig {
    fn default() -> Self {
        // 4x2 = 8 blocks (one per GPU), 4096^2 elements per block
        StencilConfig { px: 4, py: 2, block: 4096, steps: 10 }
    }
}

pub const GIN: usize = 0;
pub const GOUT: usize = 1;
pub const WEIGHTS: usize = 2;
pub const COEFF_A: usize = 3;
pub const COEFF_B: usize = 4;

pub fn stencil(cfg: StencilConfig) -> App {
    let f = 4u64;
    let block_bytes = cfg.block * cfg.block * f;
    let halo_bytes = cfg.block * f;

    let regions = vec![
        RegionDecl { name: "grid_in".into(), tile_bytes: block_bytes, fields: 1, tiles: vec![cfg.px, cfg.py] },
        RegionDecl { name: "grid_out".into(), tile_bytes: block_bytes, fields: 1, tiles: vec![cfg.px, cfg.py] },
        RegionDecl { name: "weights".into(), tile_bytes: 5 * 5 * f, fields: 1, tiles: vec![cfg.px, cfg.py] },
        RegionDecl { name: "coeff_a".into(), tile_bytes: block_bytes, fields: 1, tiles: vec![cfg.px, cfg.py] },
        RegionDecl { name: "coeff_b".into(), tile_bytes: block_bytes, fields: 1, tiles: vec![cfg.px, cfg.py] },
    ];

    let tasks = vec![
        TaskDecl {
            name: "stencil".into(),
            variants: vec![ProcKind::Gpu, ProcKind::Omp, ProcKind::Cpu],
            flops_per_point: (cfg.block * cfg.block) as f64 * 9.0,
            artifact: Some("stencil_step"),
            layout_reqs: vec![],
        },
        TaskDecl {
            name: "increment".into(),
            variants: vec![ProcKind::Gpu, ProcKind::Omp, ProcKind::Cpu],
            flops_per_point: (cfg.block * cfg.block) as f64 * 2.0,
            artifact: None,
            layout_reqs: vec![],
        },
    ];

    let (px, py) = (cfg.px, cfg.py);
    App::new(
        "stencil",
        tasks,
        regions,
        cfg.steps,
        Metric::StepsPerSecond,
        move |_step| {
            let xp = move |p: &[i64]| vec![(p[0] + 1) % px, p[1]];
            let xm = move |p: &[i64]| vec![(p[0] - 1).rem_euclid(px), p[1]];
            let yp = move |p: &[i64]| vec![p[0], (p[1] + 1) % py];
            let ym = move |p: &[i64]| vec![p[0], (p[1] - 1).rem_euclid(py)];
            vec![
                Launch {
                    task: 0,
                    ispace: vec![px, py],
                    regions: vec![
                        RegionReq::own(GIN, Access::Read, 5.0), // 5-point reuse
                        RegionReq::own(GOUT, Access::Write, 1.0),
                        RegionReq::new(GIN, Access::Read, 2.0, xp)
                            .aliased("halo_xp")
                            .bytes(halo_bytes),
                        RegionReq::new(GIN, Access::Read, 2.0, xm)
                            .aliased("halo_xm")
                            .bytes(halo_bytes),
                        RegionReq::new(GIN, Access::Read, 2.0, yp)
                            .aliased("halo_yp")
                            .bytes(halo_bytes),
                        RegionReq::new(GIN, Access::Read, 2.0, ym)
                            .aliased("halo_ym")
                            .bytes(halo_bytes),
                        RegionReq::own(WEIGHTS, Access::Read, 1.0),
                    ],
                },
                Launch {
                    task: 1,
                    ispace: vec![px, py],
                    regions: vec![
                        RegionReq::own(GIN, Access::ReadWrite, 1.0),
                        RegionReq::own(GOUT, Access::Read, 1.0),
                        RegionReq::own(COEFF_A, Access::Read, 1.0),
                        RegionReq::own(COEFF_B, Access::Read, 1.0),
                        RegionReq::own(WEIGHTS, Access::Read, 1.0),
                    ],
                },
            ]
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_search_space_shape() {
        // "2 tasks and 12 data arguments" -> 2 + 12 + 2*12 = 38 bits
        let app = stencil(StencilConfig::default());
        assert_eq!(app.tasks.len(), 2);
        assert_eq!(app.data_arguments(), 12);
        let bits = app.tasks.len() + app.data_arguments() + 2 * app.data_arguments();
        assert_eq!(bits, 38);
    }

    #[test]
    fn halo_wraps_torus_and_is_thin() {
        let app = stencil(StencilConfig::default());
        let l = app.launches(0);
        let xm = &l[0].regions[3];
        assert_eq!((xm.tile_of)(&[0, 1]), vec![3, 1]);
        assert!(xm.touched_bytes(&app.regions) < app.regions[GIN].tile_bytes / 100);
    }

    #[test]
    fn eight_blocks_default() {
        let app = stencil(StencilConfig::default());
        assert_eq!(app.launches(0)[0].num_points(), 8);
    }

    #[test]
    fn halo_alias_names_visible_to_mapper() {
        let app = stencil(StencilConfig::default());
        let l = app.launches(0);
        let names: Vec<&str> = l[0]
            .regions
            .iter()
            .map(|r| r.mapped_name(&app.regions))
            .collect();
        assert!(names.contains(&"halo_xp"));
        assert!(names.contains(&"grid_in"));
    }
}
