//! Legion-like task-graph IR (substrate S4).
//!
//! An [`App`] is a sequence of *index-task launches* per timestep over
//! logical *regions* partitioned into tiles.  The mapper (a compiled
//! [`crate::dsl::MappingPolicy`]) decides, per launch point: which
//! processor runs it, which memory each region argument lives in, and what
//! layout the instance uses.  The executor ([`crate::sim`]) charges
//! compute, memory-access, and transfer costs accordingly.
//!
//! [`task_dag`] flattens an app into per-point tasks and infers the
//! happens-before edges between them from the launches' region
//! read/write/reduce sets (Legion's logical dependence analysis, at tile
//! granularity).  The dependency-aware engine in [`crate::sim`] schedules
//! that DAG out of order; [`DepMode::Serialized`] instead emits full
//! barrier edges, which reproduces bulk-synchronous timing exactly.

use std::collections::HashMap;

use crate::machine::ProcKind;

/// Access mode of a region argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    Read,
    Write,
    ReadWrite,
    /// Reduction (associative accumulate; transfers can combine).
    Reduce,
}

/// A logical region partitioned into tiles (one tile per launch point of
/// the producing launch, or an explicit tile grid).
#[derive(Debug, Clone)]
pub struct RegionDecl {
    pub name: String,
    /// Bytes of one tile.
    pub tile_bytes: u64,
    /// Number of struct fields (AOS/SOA distinction matters above 1).
    pub fields: usize,
    /// Tile-grid extents (dimensionality = coordinate arity).
    pub tiles: Vec<i64>,
}

impl RegionDecl {
    pub fn tile_dims(&self) -> usize {
        self.tiles.len()
    }

    pub fn num_tiles(&self) -> i64 {
        self.tiles.iter().product()
    }

    /// Row-major linearization of a tile coordinate.
    pub fn tile_lin(&self, tile: &[i64]) -> i64 {
        let mut lin = 0;
        for (t, e) in tile.iter().zip(&self.tiles) {
            lin = lin * e + t;
        }
        lin
    }
}

/// Layout requirements of a task variant's precompiled kernel.  Violating
/// one produces the paper's execution errors instead of a silent remap.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayoutReq {
    /// Kernel was compiled for SOA instances (GPU coalescing); an AOS
    /// instance trips "Assertion failed: stride does not match expected
    /// value."
    pub requires_soa: bool,
    /// BLAS-backed variant requires Fortran order; C order trips
    /// "DGEMM parameter number 8 had an illegal value".
    pub requires_f_order: bool,
}

/// A task declaration: variants + cost + optional AOT artifact.
#[derive(Debug, Clone)]
pub struct TaskDecl {
    pub name: String,
    /// Processor kinds with compiled variants.
    pub variants: Vec<ProcKind>,
    /// FLOPs one launch point executes.
    pub flops_per_point: f64,
    /// Bytes the point touches per region argument are in RegionReq.
    /// Name of the AOT artifact implementing the task body (numeric mode).
    pub artifact: Option<&'static str>,
    /// Per-kind layout requirements: (kind, requirement).
    pub layout_reqs: Vec<(ProcKind, LayoutReq)>,
}

impl TaskDecl {
    pub fn layout_req(&self, kind: ProcKind) -> LayoutReq {
        self.layout_reqs
            .iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, r)| *r)
            .unwrap_or_default()
    }
}

/// One region argument of a launch: which tile each launch point touches.
pub struct RegionReq {
    /// Index into `App::regions`.
    pub region: usize,
    pub access: Access,
    /// Reuse factor: how many times the tile's bytes are effectively
    /// streamed from memory during the task (arithmetic-intensity model).
    pub reuse: f64,
    /// Tile coordinate touched by a launch point (step-specific closures —
    /// e.g. Cannon's systolic shift bakes the step into this function).
    pub tile_of: Box<dyn Fn(&[i64]) -> Vec<i64> + Send + Sync>,
    /// Name this argument exposes to `Region`/`Layout` DSL statements.
    /// Legion's ghost partitions are *views* of another logical region:
    /// e.g. the circuit's `rp_ghost` argument aliases the neighbour's
    /// `rp_shared` tile but is mapped under its own name.  None = the
    /// region's own name.
    pub alias: Option<String>,
    /// Bytes actually touched, when less than the whole tile (halo strips).
    pub bytes_override: Option<u64>,
}

impl RegionReq {
    pub fn new(
        region: usize,
        access: Access,
        reuse: f64,
        tile_of: impl Fn(&[i64]) -> Vec<i64> + Send + Sync + 'static,
    ) -> Self {
        RegionReq {
            region,
            access,
            reuse,
            tile_of: Box::new(tile_of),
            alias: None,
            bytes_override: None,
        }
    }

    /// Identity tiling: launch point (i, ..) touches tile (i, ..).
    pub fn own(region: usize, access: Access, reuse: f64) -> Self {
        Self::new(region, access, reuse, |p: &[i64]| p.to_vec())
    }

    /// Expose this argument to the mapper under a different name.
    pub fn aliased(mut self, name: impl Into<String>) -> Self {
        self.alias = Some(name.into());
        self
    }

    /// Touch only `bytes` of the tile (halo strips etc.).
    pub fn bytes(mut self, bytes: u64) -> Self {
        self.bytes_override = Some(bytes);
        self
    }

    /// The name the mapper sees for this argument.
    pub fn mapped_name<'a>(&'a self, regions: &'a [RegionDecl]) -> &'a str {
        self.alias.as_deref().unwrap_or(&regions[self.region].name)
    }

    /// Bytes this argument touches.
    pub fn touched_bytes(&self, regions: &[RegionDecl]) -> u64 {
        self.bytes_override.unwrap_or(regions[self.region].tile_bytes)
    }
}

impl std::fmt::Debug for RegionReq {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RegionReq")
            .field("region", &self.region)
            .field("access", &self.access)
            .field("reuse", &self.reuse)
            .field("alias", &self.alias)
            .finish()
    }
}

/// One index-task launch.
#[derive(Debug)]
pub struct Launch {
    /// Index into `App::tasks`.
    pub task: usize,
    /// Launch-domain extents (e.g. [4, 4] for a 4x4 grid of points).
    pub ispace: Vec<i64>,
    pub regions: Vec<RegionReq>,
}

impl Launch {
    pub fn points(&self) -> impl Iterator<Item = Vec<i64>> + '_ {
        let dims = self.ispace.clone();
        let total: i64 = dims.iter().product();
        (0..total).map(move |lin| {
            let mut rem = lin;
            let mut p = vec![0i64; dims.len()];
            for d in (0..dims.len()).rev() {
                p[d] = rem % dims[d];
                rem /= dims[d];
            }
            p
        })
    }

    pub fn num_points(&self) -> i64 {
        self.ispace.iter().product()
    }
}

/// How the app's headline metric is computed from elapsed time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Metric {
    /// GFLOP/s over the whole run (matmul algorithms).
    Gflops { total_flops: f64 },
    /// Timesteps per second (scientific apps).
    StepsPerSecond,
}

/// Where region tiles live before the first step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitialDist {
    /// Tiles materialize at their first user's chosen memory (no initial
    /// transfer charged) — scientific apps whose init tasks we elide.
    FirstUse,
    /// Tiles are pre-distributed block-wise over the GPUs' framebuffers
    /// (matmul inputs arrive distributed; fetching them is part of the
    /// algorithm's communication volume).
    BlockOverGpus,
}

/// A complete application: declarations + per-step launch generator.
pub struct App {
    pub name: String,
    pub tasks: Vec<TaskDecl>,
    pub regions: Vec<RegionDecl>,
    pub steps: usize,
    pub metric: Metric,
    pub initial_dist: InitialDist,
    /// Launches of one timestep (step index lets systolic algorithms vary
    /// their communication pattern per step).
    launch_fn: Box<dyn Fn(usize) -> Vec<Launch> + Send + Sync>,
}

impl App {
    pub fn new(
        name: impl Into<String>,
        tasks: Vec<TaskDecl>,
        regions: Vec<RegionDecl>,
        steps: usize,
        metric: Metric,
        launch_fn: impl Fn(usize) -> Vec<Launch> + Send + Sync + 'static,
    ) -> App {
        App {
            name: name.into(),
            tasks,
            regions,
            steps,
            metric,
            initial_dist: InitialDist::FirstUse,
            launch_fn: Box::new(launch_fn),
        }
    }

    pub fn with_initial_dist(mut self, dist: InitialDist) -> App {
        self.initial_dist = dist;
        self
    }

    pub fn launches(&self, step: usize) -> Vec<Launch> {
        (self.launch_fn)(step)
    }

    pub fn task_index(&self, name: &str) -> Option<usize> {
        self.tasks.iter().position(|t| t.name == name)
    }

    pub fn region_index(&self, name: &str) -> Option<usize> {
        self.regions.iter().position(|r| r.name == name)
    }

    /// Total FLOPs across all steps (for the Gflops metric + sanity).
    pub fn total_flops(&self) -> f64 {
        (0..self.steps)
            .map(|s| {
                self.launches(s)
                    .iter()
                    .map(|l| self.tasks[l.task].flops_per_point * l.num_points() as f64)
                    .sum::<f64>()
            })
            .sum()
    }

    /// Number of distinct (task, region-argument) slots — the paper's
    /// "data arguments" count that sizes the search space.
    pub fn data_arguments(&self) -> usize {
        self.launches(0).iter().map(|l| l.regions.len()).sum()
    }
}

impl std::fmt::Debug for App {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("App")
            .field("name", &self.name)
            .field("tasks", &self.tasks.len())
            .field("regions", &self.regions.len())
            .field("steps", &self.steps)
            .finish()
    }
}

// ---------------------------------------------------------------------------
// Dependency inference (happens-before edges between launch points)
// ---------------------------------------------------------------------------

/// How the task DAG's edges are derived.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DepMode {
    /// Happens-before edges inferred from the launches' region
    /// read/write/reduce sets at tile granularity (RAW, WAR, WAW;
    /// reductions into the same tile commute with each other).
    Inferred,
    /// Full edges: every point task depends on every task of the previous
    /// launch — the DAG encoding of the bulk-synchronous launch barrier.
    Serialized,
}

/// One point of one index-task launch, in program order.
#[derive(Debug, Clone)]
pub struct PointTask {
    /// Timestep the task belongs to.
    pub step: usize,
    /// Launch index within the step.
    pub launch: usize,
    /// Index into `App::tasks`.
    pub task: usize,
    /// The launch point.
    pub point: Vec<i64>,
}

/// Per-(region, tile) dependence bookkeeping during DAG construction.
#[derive(Default)]
struct TileState {
    last_writer: Option<usize>,
    /// Readers since the last write (WAR sources).
    readers: Vec<usize>,
    /// Pending reductions since the last write (commute with each other,
    /// act as writers for subsequent reads/writes).
    reducers: Vec<usize>,
}

/// Flatten `steps` (one `Vec<Launch>` per timestep, as produced by
/// [`App::launches`]) into per-point tasks plus predecessor lists.
/// Task ids are assigned in program order — (step, launch, point) — so the
/// id order is a topological order of the returned DAG.
pub fn task_dag(
    app: &App,
    steps: &[Vec<Launch>],
    mode: DepMode,
) -> (Vec<PointTask>, Vec<Vec<usize>>) {
    let mut tasks: Vec<PointTask> = Vec::new();
    let mut preds: Vec<Vec<usize>> = Vec::new();
    let mut tiles: HashMap<(usize, i64), TileState> = HashMap::new();
    let mut prev_launch: Vec<usize> = Vec::new();

    for (step, launches) in steps.iter().enumerate() {
        for (li, launch) in launches.iter().enumerate() {
            let first_id = tasks.len();
            for point in launch.points() {
                let id = tasks.len();
                let mut dd: Vec<usize> = Vec::new();
                match mode {
                    DepMode::Serialized => dd.extend_from_slice(&prev_launch),
                    DepMode::Inferred => {
                        for rr in &launch.regions {
                            let region = &app.regions[rr.region];
                            let lin = region.tile_lin(&(rr.tile_of)(&point));
                            let ts = tiles.entry((rr.region, lin)).or_default();
                            match rr.access {
                                Access::Read => {
                                    dd.extend(ts.last_writer);
                                    dd.extend_from_slice(&ts.reducers);
                                    ts.readers.push(id);
                                }
                                Access::Reduce => {
                                    dd.extend(ts.last_writer);
                                    dd.extend_from_slice(&ts.readers);
                                    ts.reducers.push(id);
                                }
                                Access::Write | Access::ReadWrite => {
                                    dd.extend(ts.last_writer);
                                    dd.extend_from_slice(&ts.readers);
                                    dd.extend_from_slice(&ts.reducers);
                                    ts.readers.clear();
                                    ts.reducers.clear();
                                    ts.last_writer = Some(id);
                                }
                            }
                        }
                    }
                }
                dd.sort_unstable();
                dd.dedup();
                dd.retain(|&p| p != id);
                preds.push(dd);
                tasks.push(PointTask { step, launch: li, task: launch.task, point });
            }
            // an empty launch leaves the barrier where it was (bulk-sync
            // keeps its clock), so it must not clear the edge source
            if mode == DepMode::Serialized && tasks.len() > first_id {
                prev_launch = (first_id..tasks.len()).collect();
            }
        }
    }
    (tasks, preds)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_app() -> App {
        App::new(
            "tiny",
            vec![TaskDecl {
                name: "work".into(),
                variants: vec![ProcKind::Gpu, ProcKind::Cpu],
                flops_per_point: 100.0,
                artifact: None,
                layout_reqs: vec![],
            }],
            vec![RegionDecl {
                name: "data".into(),
                tile_bytes: 1024,
                fields: 1,
                tiles: vec![4],
            }],
            3,
            Metric::StepsPerSecond,
            |_step| {
                vec![Launch {
                    task: 0,
                    ispace: vec![4],
                    regions: vec![RegionReq::own(0, Access::ReadWrite, 1.0)],
                }]
            },
        )
    }

    #[test]
    fn launch_point_enumeration_row_major() {
        let l = Launch { task: 0, ispace: vec![2, 3], regions: vec![] };
        let pts: Vec<Vec<i64>> = l.points().collect();
        assert_eq!(pts.len(), 6);
        assert_eq!(pts[0], vec![0, 0]);
        assert_eq!(pts[1], vec![0, 1]);
        assert_eq!(pts[5], vec![1, 2]);
    }

    #[test]
    fn total_flops_accumulates_over_steps() {
        let app = tiny_app();
        assert_eq!(app.total_flops(), 3.0 * 4.0 * 100.0);
    }

    #[test]
    fn indices_resolve() {
        let app = tiny_app();
        assert_eq!(app.task_index("work"), Some(0));
        assert_eq!(app.region_index("data"), Some(0));
        assert_eq!(app.task_index("nope"), None);
        assert_eq!(app.data_arguments(), 1);
    }

    #[test]
    fn layout_req_lookup_defaults() {
        let t = TaskDecl {
            name: "t".into(),
            variants: vec![ProcKind::Gpu],
            flops_per_point: 1.0,
            artifact: None,
            layout_reqs: vec![(
                ProcKind::Gpu,
                LayoutReq { requires_soa: true, requires_f_order: false },
            )],
        };
        assert!(t.layout_req(ProcKind::Gpu).requires_soa);
        assert!(!t.layout_req(ProcKind::Cpu).requires_soa);
    }

    #[test]
    fn region_req_custom_tiling() {
        let r = RegionReq::new(0, Access::Read, 1.0, |p: &[i64]| {
            vec![(p[0] + 1) % 4, p[1]]
        });
        assert_eq!((r.tile_of)(&[3, 2]), vec![0, 2]);
    }

    fn dag_of(app: &App, mode: DepMode) -> (Vec<PointTask>, Vec<Vec<usize>>) {
        let steps: Vec<Vec<Launch>> = (0..app.steps).map(|s| app.launches(s)).collect();
        task_dag(app, &steps, mode)
    }

    #[test]
    fn serialized_dag_encodes_launch_barriers() {
        let app = tiny_app(); // 3 steps x 1 launch x 4 points
        let (tasks, preds) = dag_of(&app, DepMode::Serialized);
        assert_eq!(tasks.len(), 12);
        for i in 0..4 {
            assert!(preds[i].is_empty(), "first launch must be root");
        }
        for i in 4..8 {
            assert_eq!(preds[i], vec![0, 1, 2, 3]);
        }
        for i in 8..12 {
            assert_eq!(preds[i], vec![4, 5, 6, 7]);
        }
    }

    #[test]
    fn inferred_dag_chains_readwrite_tiles() {
        // tiny_app: one RW region, identity tiling -> per-point chains
        let app = tiny_app();
        let (tasks, preds) = dag_of(&app, DepMode::Inferred);
        assert_eq!(tasks.len(), 12);
        for i in 0..4 {
            assert!(preds[i].is_empty());
        }
        for i in 4..12 {
            // point p at step s depends only on point p at step s-1
            assert_eq!(preds[i], vec![i - 4]);
        }
    }

    #[test]
    fn inferred_circuit_deps_follow_ghost_neighbourhood() {
        // CNC ids 0..8, DC ids 8..16, UV ids 16..24 (step 0), CNC' 24..32.
        let app = crate::apps::circuit(crate::apps::CircuitConfig::default());
        let (tasks, preds) = dag_of(&app, DepMode::Inferred);
        assert_eq!(tasks[8].task, 1, "id 8 is distribute_charge piece 0");
        // DC piece 0 reduces shared tiles 0 and 1, whose readers are the
        // CNC tasks of pieces 7, 0, 1 (ghost reads wrap around).
        assert_eq!(preds[8], vec![0, 1, 7]);
        // UV piece 0 writes shared tile 0: WAR on CNC 7/0, plus the
        // pending reductions of DC 7/0 and its private-tile chain.
        assert_eq!(preds[16], vec![0, 7, 8, 15]);
        // Next step's CNC piece 0 reads what UV pieces 0/1 wrote and
        // rewrites its wires (read by DC 0).
        assert_eq!(preds[24], vec![0, 8, 16, 17]);
    }

    #[test]
    fn inferred_cannon_is_per_point_chains() {
        // A/B tiles are read-only; only the C tile chains a point to its
        // own previous step -> 16 independent pipelines.
        let app = crate::apps::matmul(
            crate::apps::Algorithm::Cannon,
            crate::apps::MatmulConfig::default(),
        );
        let (tasks, preds) = dag_of(&app, DepMode::Inferred);
        assert_eq!(tasks.len(), 64); // 4 steps x 16 points
        for i in 0..16 {
            assert!(preds[i].is_empty());
        }
        for i in 16..64 {
            assert_eq!(preds[i], vec![i - 16]);
        }
    }
}
