//! Legion-like task-graph IR (substrate S4).
//!
//! An [`App`] is a sequence of *index-task launches* per timestep over
//! logical *regions* partitioned into tiles.  The mapper (a compiled
//! [`crate::dsl::MappingPolicy`]) decides, per launch point: which
//! processor runs it, which memory each region argument lives in, and what
//! layout the instance uses.  The executor ([`crate::sim`]) charges
//! compute, memory-access, and transfer costs accordingly.
//!
//! [`task_dag`] flattens an app into per-point tasks and infers the
//! happens-before edges between them from the launches' region
//! read/write/reduce sets (Legion's logical dependence analysis, at tile
//! granularity).  The dependency-aware engine in [`crate::sim`] schedules
//! that DAG out of order; [`DepMode::Serialized`] instead encodes the
//! bulk-synchronous launch barrier, which reproduces its timing exactly.
//!
//! # Barrier compression (the 10^5-task encoding)
//!
//! Dense dependence patterns are routed through zero-duration *synthetic
//! nodes* instead of materializing cross-product edge sets, so the DAG
//! stays linear in the number of point tasks:
//!
//! * `Serialized`: a launch barrier between two P-point launches is one
//!   barrier node (P in-edges, P out-edges) rather than the P^2 bipartite
//!   edge set.  A single-point launch acts as its own barrier.
//! * `Inferred`: when a consumer would depend on a tile's full reader (or
//!   pending-reducer) set and that set has [`GATE_FANIN`] or more
//!   members, the set is collapsed through a memoized *gate* node shared
//!   by every consumer of the same set — broadcast-read-then-write
//!   patterns cost O(P) edges instead of O(P^2).
//!
//! Synthetic nodes carry no point task ([`TaskDag::point_of`] returns
//! `None`), take zero time, and are timing-neutral: a consumer's ready
//! time is still the max end time of the real predecessors behind the
//! node.  The DAG is returned as a [`TaskDag`]: CSR (offset + data)
//! predecessor/successor adjacency over node ids in topological order,
//! with the launch-point coordinates packed in one flat arena instead of
//! one heap `Vec` per task.

use std::collections::HashMap;

use crate::machine::ProcKind;

/// Access mode of a region argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    Read,
    Write,
    ReadWrite,
    /// Reduction (associative accumulate; transfers can combine).
    Reduce,
}

/// A logical region partitioned into tiles (one tile per launch point of
/// the producing launch, or an explicit tile grid).
#[derive(Debug, Clone)]
pub struct RegionDecl {
    pub name: String,
    /// Bytes of one tile.
    pub tile_bytes: u64,
    /// Number of struct fields (AOS/SOA distinction matters above 1).
    pub fields: usize,
    /// Tile-grid extents (dimensionality = coordinate arity).
    pub tiles: Vec<i64>,
}

impl RegionDecl {
    pub fn tile_dims(&self) -> usize {
        self.tiles.len()
    }

    pub fn num_tiles(&self) -> i64 {
        self.tiles.iter().product()
    }

    /// Row-major linearization of a tile coordinate.
    pub fn tile_lin(&self, tile: &[i64]) -> i64 {
        let mut lin = 0;
        for (t, e) in tile.iter().zip(&self.tiles) {
            lin = lin * e + t;
        }
        lin
    }
}

/// Layout requirements of a task variant's precompiled kernel.  Violating
/// one produces the paper's execution errors instead of a silent remap.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayoutReq {
    /// Kernel was compiled for SOA instances (GPU coalescing); an AOS
    /// instance trips "Assertion failed: stride does not match expected
    /// value."
    pub requires_soa: bool,
    /// BLAS-backed variant requires Fortran order; C order trips
    /// "DGEMM parameter number 8 had an illegal value".
    pub requires_f_order: bool,
}

/// A task declaration: variants + cost + optional AOT artifact.
#[derive(Debug, Clone)]
pub struct TaskDecl {
    pub name: String,
    /// Processor kinds with compiled variants.
    pub variants: Vec<ProcKind>,
    /// FLOPs one launch point executes.
    pub flops_per_point: f64,
    /// Bytes the point touches per region argument are in RegionReq.
    /// Name of the AOT artifact implementing the task body (numeric mode).
    pub artifact: Option<&'static str>,
    /// Per-kind layout requirements: (kind, requirement).
    pub layout_reqs: Vec<(ProcKind, LayoutReq)>,
}

impl TaskDecl {
    pub fn layout_req(&self, kind: ProcKind) -> LayoutReq {
        self.layout_reqs
            .iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, r)| *r)
            .unwrap_or_default()
    }
}

/// One region argument of a launch: which tile each launch point touches.
pub struct RegionReq {
    /// Index into `App::regions`.
    pub region: usize,
    pub access: Access,
    /// Reuse factor: how many times the tile's bytes are effectively
    /// streamed from memory during the task (arithmetic-intensity model).
    pub reuse: f64,
    /// Tile coordinate touched by a launch point (step-specific closures —
    /// e.g. Cannon's systolic shift bakes the step into this function).
    pub tile_of: Box<dyn Fn(&[i64]) -> Vec<i64> + Send + Sync>,
    /// Name this argument exposes to `Region`/`Layout` DSL statements.
    /// Legion's ghost partitions are *views* of another logical region:
    /// e.g. the circuit's `rp_ghost` argument aliases the neighbour's
    /// `rp_shared` tile but is mapped under its own name.  None = the
    /// region's own name.
    pub alias: Option<String>,
    /// Bytes actually touched, when less than the whole tile (halo strips).
    pub bytes_override: Option<u64>,
}

impl RegionReq {
    pub fn new(
        region: usize,
        access: Access,
        reuse: f64,
        tile_of: impl Fn(&[i64]) -> Vec<i64> + Send + Sync + 'static,
    ) -> Self {
        RegionReq {
            region,
            access,
            reuse,
            tile_of: Box::new(tile_of),
            alias: None,
            bytes_override: None,
        }
    }

    /// Identity tiling: launch point (i, ..) touches tile (i, ..).
    pub fn own(region: usize, access: Access, reuse: f64) -> Self {
        Self::new(region, access, reuse, |p: &[i64]| p.to_vec())
    }

    /// Expose this argument to the mapper under a different name.
    pub fn aliased(mut self, name: impl Into<String>) -> Self {
        self.alias = Some(name.into());
        self
    }

    /// Touch only `bytes` of the tile (halo strips etc.).
    pub fn bytes(mut self, bytes: u64) -> Self {
        self.bytes_override = Some(bytes);
        self
    }

    /// The name the mapper sees for this argument.
    pub fn mapped_name<'a>(&'a self, regions: &'a [RegionDecl]) -> &'a str {
        self.alias.as_deref().unwrap_or(&regions[self.region].name)
    }

    /// Bytes this argument touches.
    pub fn touched_bytes(&self, regions: &[RegionDecl]) -> u64 {
        self.bytes_override.unwrap_or(regions[self.region].tile_bytes)
    }
}

impl std::fmt::Debug for RegionReq {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RegionReq")
            .field("region", &self.region)
            .field("access", &self.access)
            .field("reuse", &self.reuse)
            .field("alias", &self.alias)
            .finish()
    }
}

/// One index-task launch.
#[derive(Debug)]
pub struct Launch {
    /// Index into `App::tasks`.
    pub task: usize,
    /// Launch-domain extents (e.g. [4, 4] for a 4x4 grid of points).
    pub ispace: Vec<i64>,
    pub regions: Vec<RegionReq>,
}

impl Launch {
    pub fn points(&self) -> impl Iterator<Item = Vec<i64>> + '_ {
        let dims = self.ispace.clone();
        let total: i64 = dims.iter().product();
        (0..total).map(move |lin| {
            let mut rem = lin;
            let mut p = vec![0i64; dims.len()];
            for d in (0..dims.len()).rev() {
                p[d] = rem % dims[d];
                rem /= dims[d];
            }
            p
        })
    }

    pub fn num_points(&self) -> i64 {
        self.ispace.iter().product()
    }
}

/// How the app's headline metric is computed from elapsed time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Metric {
    /// GFLOP/s over the whole run (matmul algorithms).
    Gflops { total_flops: f64 },
    /// Timesteps per second (scientific apps).
    StepsPerSecond,
}

/// Where region tiles live before the first step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitialDist {
    /// Tiles materialize at their first user's chosen memory (no initial
    /// transfer charged) — scientific apps whose init tasks we elide.
    FirstUse,
    /// Tiles are pre-distributed block-wise over the GPUs' framebuffers
    /// (matmul inputs arrive distributed; fetching them is part of the
    /// algorithm's communication volume).
    BlockOverGpus,
}

/// A complete application: declarations + per-step launch generator.
pub struct App {
    pub name: String,
    pub tasks: Vec<TaskDecl>,
    pub regions: Vec<RegionDecl>,
    pub steps: usize,
    pub metric: Metric,
    pub initial_dist: InitialDist,
    /// Launches of one timestep (step index lets systolic algorithms vary
    /// their communication pattern per step).
    launch_fn: Box<dyn Fn(usize) -> Vec<Launch> + Send + Sync>,
}

impl App {
    pub fn new(
        name: impl Into<String>,
        tasks: Vec<TaskDecl>,
        regions: Vec<RegionDecl>,
        steps: usize,
        metric: Metric,
        launch_fn: impl Fn(usize) -> Vec<Launch> + Send + Sync + 'static,
    ) -> App {
        App {
            name: name.into(),
            tasks,
            regions,
            steps,
            metric,
            initial_dist: InitialDist::FirstUse,
            launch_fn: Box::new(launch_fn),
        }
    }

    pub fn with_initial_dist(mut self, dist: InitialDist) -> App {
        self.initial_dist = dist;
        self
    }

    pub fn launches(&self, step: usize) -> Vec<Launch> {
        (self.launch_fn)(step)
    }

    pub fn task_index(&self, name: &str) -> Option<usize> {
        self.tasks.iter().position(|t| t.name == name)
    }

    pub fn region_index(&self, name: &str) -> Option<usize> {
        self.regions.iter().position(|r| r.name == name)
    }

    /// Total FLOPs across all steps (for the Gflops metric + sanity).
    pub fn total_flops(&self) -> f64 {
        (0..self.steps)
            .map(|s| {
                self.launches(s)
                    .iter()
                    .map(|l| self.tasks[l.task].flops_per_point * l.num_points() as f64)
                    .sum::<f64>()
            })
            .sum()
    }

    /// Number of distinct (task, region-argument) slots — the paper's
    /// "data arguments" count that sizes the search space.
    pub fn data_arguments(&self) -> usize {
        self.launches(0).iter().map(|l| l.regions.len()).sum()
    }
}

impl std::fmt::Debug for App {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("App")
            .field("name", &self.name)
            .field("tasks", &self.tasks.len())
            .field("regions", &self.regions.len())
            .field("steps", &self.steps)
            .finish()
    }
}

// ---------------------------------------------------------------------------
// Dependency inference (happens-before edges between launch points)
// ---------------------------------------------------------------------------

/// How the task DAG's edges are derived.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DepMode {
    /// Happens-before edges inferred from the launches' region
    /// read/write/reduce sets at tile granularity (RAW, WAR, WAW;
    /// reductions into the same tile commute with each other).
    Inferred,
    /// Full edges: every point task depends on every task of the previous
    /// launch — the DAG encoding of the bulk-synchronous launch barrier.
    Serialized,
}

/// One point of one index-task launch, in program order.  The launch
/// point's coordinates live in the owning [`TaskDag`]'s flat arena
/// ([`TaskDag::coords`]).
#[derive(Debug, Clone)]
pub struct PointTask {
    /// Timestep the task belongs to.
    pub step: usize,
    /// Launch index within the step.
    pub launch: usize,
    /// Index into `App::tasks`.
    pub task: usize,
}

/// Reader/reducer fan-in at which Inferred-mode dependence sets are
/// collapsed through a gate node (below it, direct edges are cheaper).
pub const GATE_FANIN: usize = 8;

/// Sentinel in `TaskDag::node_point` marking a synthetic node.
const NO_POINT: u32 = u32::MAX;

/// Compressed sparse adjacency: row `i` of `off`/`dat` holds the
/// neighbours of node `i` (ascending node ids).
#[derive(Debug, Clone, Default)]
pub struct Csr {
    off: Vec<u32>,
    dat: Vec<u32>,
}

impl Csr {
    fn from_lists(lists: &[Vec<u32>]) -> Csr {
        let mut off = Vec::with_capacity(lists.len() + 1);
        off.push(0u32);
        let total: usize = lists.iter().map(|l| l.len()).sum();
        let mut dat = Vec::with_capacity(total);
        for l in lists {
            dat.extend_from_slice(l);
            off.push(dat.len() as u32);
        }
        Csr { off, dat }
    }

    /// Transpose of `lists`: row `i` holds every `j` with `i` in
    /// `lists[j]`, ascending (successors from predecessor lists).
    fn transpose(lists: &[Vec<u32>]) -> Csr {
        let n = lists.len();
        let mut off = vec![0u32; n + 1];
        for l in lists {
            for &p in l {
                off[p as usize + 1] += 1;
            }
        }
        for i in 0..n {
            off[i + 1] += off[i];
        }
        let mut cur: Vec<u32> = off[..n].to_vec();
        let mut dat = vec![0u32; off[n] as usize];
        for (j, l) in lists.iter().enumerate() {
            for &p in l {
                dat[cur[p as usize] as usize] = j as u32;
                cur[p as usize] += 1;
            }
        }
        Csr { off, dat }
    }

    pub fn row(&self, i: usize) -> &[u32] {
        &self.dat[self.off[i] as usize..self.off[i + 1] as usize]
    }

    pub fn num_edges(&self) -> usize {
        self.dat.len()
    }
}

/// The flattened task graph: point tasks in program order plus CSR
/// adjacency over *nodes* (point tasks interleaved with the synthetic
/// barrier/gate nodes of the compressed encoding).  Node ids are in
/// topological order; point tasks appear in program order within it.
#[derive(Debug, Clone, Default)]
pub struct TaskDag {
    points: Vec<PointTask>,
    /// Flat coordinate arena: point `i`'s coordinates are
    /// `coords[coord_off[i]..coord_off[i + 1]]`.
    coords: Vec<i64>,
    coord_off: Vec<u32>,
    /// Per node: index into `points`, or `NO_POINT` for synthetic nodes.
    node_point: Vec<u32>,
    preds: Csr,
    succs: Csr,
}

impl TaskDag {
    pub fn num_points(&self) -> usize {
        self.points.len()
    }

    pub fn num_nodes(&self) -> usize {
        self.node_point.len()
    }

    /// Total predecessor edges (what barrier compression keeps O(n)).
    pub fn num_edges(&self) -> usize {
        self.preds.num_edges()
    }

    pub fn point(&self, i: usize) -> &PointTask {
        &self.points[i]
    }

    pub fn points(&self) -> &[PointTask] {
        &self.points
    }

    /// Coordinates of point task `i` (program-order index, not node id).
    pub fn coords(&self, i: usize) -> &[i64] {
        &self.coords[self.coord_off[i] as usize..self.coord_off[i + 1] as usize]
    }

    /// Point-task index of a node, or `None` for synthetic nodes.
    pub fn point_of(&self, node: usize) -> Option<usize> {
        let p = self.node_point[node];
        (p != NO_POINT).then_some(p as usize)
    }

    pub fn preds_of(&self, node: usize) -> &[u32] {
        self.preds.row(node)
    }

    /// Predecessor fan-in of every node — the scheduler's initial
    /// in-degree vector.  Cached once per [`crate::sim::EvalPlan`] so a
    /// warm evaluation copies it instead of re-walking the CSR rows.
    pub fn pred_counts(&self) -> Vec<u32> {
        (0..self.num_nodes())
            .map(|i| self.preds_of(i).len() as u32)
            .collect()
    }

    pub fn succs_of(&self, node: usize) -> &[u32] {
        self.succs.row(node)
    }
}

/// Per-(region, tile) dependence bookkeeping during DAG construction.
#[derive(Default)]
struct TileState {
    last_writer: Option<u32>,
    /// Readers since the last write (WAR sources).
    readers: Vec<u32>,
    /// Pending reductions since the last write (commute with each other,
    /// act as writers for subsequent reads/writes).
    reducers: Vec<u32>,
    /// Memoized gate nodes standing in for the *current* readers /
    /// reducers sets; invalidated whenever the underlying set changes.
    readers_gate: Option<u32>,
    reducers_gate: Option<u32>,
}

/// Depend on `sources`: directly below the `gate_fanin` threshold,
/// through a shared (memoized) gate node at or above it.
fn gate_deps(
    dd: &mut Vec<u32>,
    sources: &[u32],
    gate: &mut Option<u32>,
    gate_fanin: usize,
    node_point: &mut Vec<u32>,
    pred_lists: &mut Vec<Vec<u32>>,
) {
    if sources.len() < gate_fanin {
        dd.extend_from_slice(sources);
        return;
    }
    let g = *gate.get_or_insert_with(|| {
        node_point.push(NO_POINT);
        pred_lists.push(sources.to_vec());
        (node_point.len() - 1) as u32
    });
    dd.push(g);
}

/// Flatten `steps` (one `Vec<Launch>` per timestep, as produced by
/// [`App::launches`]) into a [`TaskDag`].  Node ids are assigned in
/// creation order — gates/barriers always before their consumers — so
/// the id order is a topological order of the returned DAG, and point
/// tasks keep program order (step, launch, point).
pub fn task_dag(app: &App, steps: &[Vec<Launch>], mode: DepMode) -> TaskDag {
    task_dag_with_gate_fanin(app, steps, mode, GATE_FANIN)
}

/// [`task_dag`] with an explicit gate-compression threshold — a test
/// hook for the compression invariants: `2` forces every multi-member
/// reader/reducer set through a gate node, `usize::MAX` disables gates
/// entirely (the uncompressed reference DAG).  Gate nodes are
/// timing-neutral by construction, so per-node earliest-start times and
/// the critical path must be threshold-independent;
/// `tests/property_suite.rs` fuzzes exactly that.
pub fn task_dag_with_gate_fanin(
    app: &App,
    steps: &[Vec<Launch>],
    mode: DepMode,
    gate_fanin: usize,
) -> TaskDag {
    let mut points: Vec<PointTask> = Vec::new();
    let mut coords: Vec<i64> = Vec::new();
    let mut coord_off: Vec<u32> = vec![0];
    let mut node_point: Vec<u32> = Vec::new();
    let mut pred_lists: Vec<Vec<u32>> = Vec::new();
    let mut tiles: HashMap<(usize, i64), TileState> = HashMap::new();
    // Serialized barrier bookkeeping: the previous non-empty launch's
    // point-node range, and the lazily created barrier standing in for
    // it.  An empty launch leaves the barrier where it was (bulk-sync
    // keeps its clock), so it must not clear the edge source.
    let mut prev_range: Option<(u32, u32)> = None;
    let mut prev_barrier: Option<u32> = None;
    // per-point scratch: (region, tile lin) of each region req, computed
    // once in the dependency phase and reused by the registration phase
    let mut tile_scratch: Vec<(usize, i64)> = Vec::new();

    for (step, launches) in steps.iter().enumerate() {
        for (li, launch) in launches.iter().enumerate() {
            let mut first_point_node: Option<u32> = None;
            let mut last_point_node = 0u32;
            for point in launch.points() {
                // ---- dependencies (may allocate gate/barrier nodes) ----
                let mut dd: Vec<u32> = Vec::new();
                match mode {
                    DepMode::Serialized => {
                        if let Some((lo, hi)) = prev_range {
                            let b = *prev_barrier.get_or_insert_with(|| {
                                if hi - lo == 1 {
                                    lo // a single point is its own barrier
                                } else {
                                    node_point.push(NO_POINT);
                                    pred_lists.push((lo..hi).collect());
                                    (node_point.len() - 1) as u32
                                }
                            });
                            dd.push(b);
                        }
                    }
                    DepMode::Inferred => {
                        tile_scratch.clear();
                        for rr in &launch.regions {
                            let region = &app.regions[rr.region];
                            let lin = region.tile_lin(&(rr.tile_of)(&point));
                            tile_scratch.push((rr.region, lin));
                            let ts = tiles.entry((rr.region, lin)).or_default();
                            match rr.access {
                                Access::Read => {
                                    dd.extend(ts.last_writer);
                                    gate_deps(
                                        &mut dd,
                                        &ts.reducers,
                                        &mut ts.reducers_gate,
                                        gate_fanin,
                                        &mut node_point,
                                        &mut pred_lists,
                                    );
                                }
                                Access::Reduce => {
                                    dd.extend(ts.last_writer);
                                    gate_deps(
                                        &mut dd,
                                        &ts.readers,
                                        &mut ts.readers_gate,
                                        gate_fanin,
                                        &mut node_point,
                                        &mut pred_lists,
                                    );
                                }
                                Access::Write | Access::ReadWrite => {
                                    dd.extend(ts.last_writer);
                                    gate_deps(
                                        &mut dd,
                                        &ts.readers,
                                        &mut ts.readers_gate,
                                        gate_fanin,
                                        &mut node_point,
                                        &mut pred_lists,
                                    );
                                    gate_deps(
                                        &mut dd,
                                        &ts.reducers,
                                        &mut ts.reducers_gate,
                                        gate_fanin,
                                        &mut node_point,
                                        &mut pred_lists,
                                    );
                                }
                            }
                        }
                    }
                }
                dd.sort_unstable();
                dd.dedup();

                // ---- allocate the point node ---------------------------
                let id = node_point.len() as u32;
                node_point.push(points.len() as u32);
                pred_lists.push(dd);
                if first_point_node.is_none() {
                    first_point_node = Some(id);
                }
                last_point_node = id;

                // ---- register this point's accesses --------------------
                // (reader/reducer sets stay ascending and duplicate-free:
                // two region reqs of one point can wrap onto the same
                // tile, and `id` is always the largest id so far)
                if mode == DepMode::Inferred {
                    for (rr, &key) in launch.regions.iter().zip(&tile_scratch) {
                        let ts = tiles.entry(key).or_default();
                        match rr.access {
                            Access::Read => {
                                if ts.readers.last() != Some(&id) {
                                    ts.readers.push(id);
                                    ts.readers_gate = None;
                                }
                            }
                            Access::Reduce => {
                                if ts.reducers.last() != Some(&id) {
                                    ts.reducers.push(id);
                                    ts.reducers_gate = None;
                                }
                            }
                            Access::Write | Access::ReadWrite => {
                                ts.readers.clear();
                                ts.reducers.clear();
                                ts.readers_gate = None;
                                ts.reducers_gate = None;
                                ts.last_writer = Some(id);
                            }
                        }
                    }
                }
                coords.extend_from_slice(&point);
                coord_off.push(coords.len() as u32);
                points.push(PointTask { step, launch: li, task: launch.task });
            }
            if mode == DepMode::Serialized {
                if let Some(first) = first_point_node {
                    prev_range = Some((first, last_point_node + 1));
                    prev_barrier = None;
                }
            }
        }
    }

    let preds = Csr::from_lists(&pred_lists);
    let succs = Csr::transpose(&pred_lists);
    TaskDag { points, coords, coord_off, node_point, preds, succs }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_app() -> App {
        App::new(
            "tiny",
            vec![TaskDecl {
                name: "work".into(),
                variants: vec![ProcKind::Gpu, ProcKind::Cpu],
                flops_per_point: 100.0,
                artifact: None,
                layout_reqs: vec![],
            }],
            vec![RegionDecl {
                name: "data".into(),
                tile_bytes: 1024,
                fields: 1,
                tiles: vec![4],
            }],
            3,
            Metric::StepsPerSecond,
            |_step| {
                vec![Launch {
                    task: 0,
                    ispace: vec![4],
                    regions: vec![RegionReq::own(0, Access::ReadWrite, 1.0)],
                }]
            },
        )
    }

    #[test]
    fn launch_point_enumeration_row_major() {
        let l = Launch { task: 0, ispace: vec![2, 3], regions: vec![] };
        let pts: Vec<Vec<i64>> = l.points().collect();
        assert_eq!(pts.len(), 6);
        assert_eq!(pts[0], vec![0, 0]);
        assert_eq!(pts[1], vec![0, 1]);
        assert_eq!(pts[5], vec![1, 2]);
    }

    #[test]
    fn total_flops_accumulates_over_steps() {
        let app = tiny_app();
        assert_eq!(app.total_flops(), 3.0 * 4.0 * 100.0);
    }

    #[test]
    fn indices_resolve() {
        let app = tiny_app();
        assert_eq!(app.task_index("work"), Some(0));
        assert_eq!(app.region_index("data"), Some(0));
        assert_eq!(app.task_index("nope"), None);
        assert_eq!(app.data_arguments(), 1);
    }

    #[test]
    fn layout_req_lookup_defaults() {
        let t = TaskDecl {
            name: "t".into(),
            variants: vec![ProcKind::Gpu],
            flops_per_point: 1.0,
            artifact: None,
            layout_reqs: vec![(
                ProcKind::Gpu,
                LayoutReq { requires_soa: true, requires_f_order: false },
            )],
        };
        assert!(t.layout_req(ProcKind::Gpu).requires_soa);
        assert!(!t.layout_req(ProcKind::Cpu).requires_soa);
    }

    #[test]
    fn region_req_custom_tiling() {
        let r = RegionReq::new(0, Access::Read, 1.0, |p: &[i64]| {
            vec![(p[0] + 1) % 4, p[1]]
        });
        assert_eq!((r.tile_of)(&[3, 2]), vec![0, 2]);
    }

    fn dag_of(app: &App, mode: DepMode) -> TaskDag {
        let steps: Vec<Vec<Launch>> = (0..app.steps).map(|s| app.launches(s)).collect();
        task_dag(app, &steps, mode)
    }

    #[test]
    fn serialized_dag_encodes_launch_barriers() {
        let app = tiny_app(); // 3 steps x 1 launch x 4 points
        let dag = dag_of(&app, DepMode::Serialized);
        assert_eq!(dag.num_points(), 12);
        // nodes: 4 points, barrier, 4 points, barrier, 4 points
        assert_eq!(dag.num_nodes(), 14);
        for node in 0..4 {
            assert!(dag.preds_of(node).is_empty(), "first launch must be root");
            assert_eq!(dag.point_of(node), Some(node));
        }
        assert_eq!(dag.point_of(4), None, "node 4 is the first launch barrier");
        assert_eq!(dag.preds_of(4), &[0u32, 1, 2, 3][..]);
        for node in 5..9 {
            assert_eq!(dag.preds_of(node), &[4u32][..]);
        }
        assert_eq!(dag.point_of(9), None);
        assert_eq!(dag.preds_of(9), &[5u32, 6, 7, 8][..]);
        for node in 10..14 {
            assert_eq!(dag.preds_of(node), &[9u32][..]);
        }
    }

    #[test]
    fn serialized_barrier_edges_linear_in_launch_width() {
        // a P-point launch per step must cost O(P) edges per launch pair,
        // not the P^2 bipartite barrier
        let p = 64i64;
        let steps = 4usize;
        let app = App::new(
            "wide",
            vec![TaskDecl {
                name: "work".into(),
                variants: vec![ProcKind::Gpu],
                flops_per_point: 1.0,
                artifact: None,
                layout_reqs: vec![],
            }],
            vec![RegionDecl {
                name: "data".into(),
                tile_bytes: 64,
                fields: 1,
                tiles: vec![p],
            }],
            steps,
            Metric::StepsPerSecond,
            move |_| {
                vec![Launch {
                    task: 0,
                    ispace: vec![p],
                    regions: vec![RegionReq::own(0, Access::ReadWrite, 1.0)],
                }]
            },
        );
        let dag = dag_of(&app, DepMode::Serialized);
        assert_eq!(dag.num_points(), (p as usize) * steps);
        // one barrier node between consecutive launches
        assert_eq!(dag.num_nodes(), (p as usize) * steps + (steps - 1));
        // each barrier: P in-edges + P out-edges
        assert_eq!(dag.num_edges(), (steps - 1) * 2 * p as usize);
    }

    #[test]
    fn inferred_gate_compresses_reader_cross_products() {
        // one shared tile read by 16 points then reduced by 16 points:
        // the reduce launch must depend through one gate node (2P edges),
        // not the P^2 readers-x-reducers cross product
        let p = 16i64;
        let app = App::new(
            "fan",
            vec![TaskDecl {
                name: "t".into(),
                variants: vec![ProcKind::Gpu],
                flops_per_point: 1.0,
                artifact: None,
                layout_reqs: vec![],
            }],
            vec![RegionDecl {
                name: "acc".into(),
                tile_bytes: 64,
                fields: 1,
                tiles: vec![1],
            }],
            1,
            Metric::StepsPerSecond,
            move |_| {
                vec![
                    Launch {
                        task: 0,
                        ispace: vec![p],
                        regions: vec![RegionReq::new(0, Access::Read, 1.0, |_| vec![0])],
                    },
                    Launch {
                        task: 0,
                        ispace: vec![p],
                        regions: vec![RegionReq::new(0, Access::Reduce, 1.0, |_| {
                            vec![0]
                        })],
                    },
                ]
            },
        );
        let dag = dag_of(&app, DepMode::Inferred);
        // nodes: 16 readers, 1 gate, 16 reducers
        assert_eq!(dag.num_points(), 32);
        assert_eq!(dag.num_nodes(), 33);
        assert_eq!(dag.point_of(16), None, "node 16 is the readers gate");
        assert_eq!(dag.preds_of(16).len(), p as usize);
        for node in 17..33 {
            assert_eq!(dag.preds_of(node), &[16u32][..]);
        }
        assert_eq!(dag.num_edges(), 2 * p as usize);
    }

    #[test]
    fn inferred_dag_chains_readwrite_tiles() {
        // tiny_app: one RW region, identity tiling -> per-point chains
        // (fan-in 1 everywhere, so no gate nodes: node id == point id)
        let app = tiny_app();
        let dag = dag_of(&app, DepMode::Inferred);
        assert_eq!(dag.num_points(), 12);
        assert_eq!(dag.num_nodes(), 12);
        for i in 0..4 {
            assert!(dag.preds_of(i).is_empty());
        }
        for i in 4..12 {
            // point p at step s depends only on point p at step s-1
            assert_eq!(dag.preds_of(i), &[(i - 4) as u32][..]);
        }
    }

    #[test]
    fn inferred_circuit_deps_follow_ghost_neighbourhood() {
        // CNC ids 0..8, DC ids 8..16, UV ids 16..24 (step 0), CNC' 24..32.
        // All fan-ins sit below GATE_FANIN, so node ids equal point ids.
        let app = crate::apps::circuit(crate::apps::CircuitConfig::default());
        let dag = dag_of(&app, DepMode::Inferred);
        assert_eq!(dag.num_nodes(), dag.num_points(), "no gates expected");
        assert_eq!(dag.point(8).task, 1, "id 8 is distribute_charge piece 0");
        // DC piece 0 reduces shared tiles 0 and 1, whose readers are the
        // CNC tasks of pieces 7, 0, 1 (ghost reads wrap around).
        assert_eq!(dag.preds_of(8), &[0u32, 1, 7][..]);
        // UV piece 0 writes shared tile 0: WAR on CNC 7/0, plus the
        // pending reductions of DC 7/0 and its private-tile chain.
        assert_eq!(dag.preds_of(16), &[0u32, 7, 8, 15][..]);
        // Next step's CNC piece 0 reads what UV pieces 0/1 wrote and
        // rewrites its wires (read by DC 0).
        assert_eq!(dag.preds_of(24), &[0u32, 8, 16, 17][..]);
    }

    #[test]
    fn inferred_cannon_is_per_point_chains() {
        // A/B tiles are read-only; only the C tile chains a point to its
        // own previous step -> 16 independent pipelines.
        let app = crate::apps::matmul(
            crate::apps::Algorithm::Cannon,
            crate::apps::MatmulConfig::default(),
        );
        let dag = dag_of(&app, DepMode::Inferred);
        assert_eq!(dag.num_points(), 64); // 4 steps x 16 points
        assert_eq!(dag.num_nodes(), 64);
        for i in 0..16 {
            assert!(dag.preds_of(i).is_empty());
        }
        for i in 16..64 {
            assert_eq!(dag.preds_of(i), &[(i - 16) as u32][..]);
        }
    }

    #[test]
    fn coordinate_arena_matches_launch_enumeration() {
        let app = tiny_app();
        let dag = dag_of(&app, DepMode::Serialized);
        let l = app.launches(0);
        let expected: Vec<Vec<i64>> = l[0].points().collect();
        for i in 0..4 {
            assert_eq!(dag.coords(i), expected[i].as_slice());
            assert_eq!(dag.coords(i + 4), expected[i].as_slice(), "step 1 repeats");
        }
    }
}
