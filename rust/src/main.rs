//! `mapperopt` — CLI for the mapper-optimization framework.
//!
//! Subcommands:
//!   table1                    — mapper LoC, DSL vs C++ (paper Table 1)
//!   table3                    — strategy->code generation (paper Table 3)
//!   fig6 / fig7 / fig8        — the evaluation figures
//!   all                       — every table and figure in sequence
//!   run --app A [--mapper F]  — execute one app under a mapper (expert
//!                               default), print metrics
//!   optimize --app A [...]    — one optimization campaign, live log
//!   bench-suite               — quick end-to-end status of all benchmarks
//!
//! Common flags: --iters N --runs N --seed S --algo trace|opro
//!               --feedback system|explain|full --workers N
//!
//! Every evaluation flows through one process-wide [`EvalService`] (the
//! serving layer): the CLI's coordinator is a thin client of it, and the
//! `all` / `bench-suite` subcommands print the service's queue/cache
//! statistics on exit.

use std::process::ExitCode;
use std::sync::Arc;

use mapperopt::apps;
use mapperopt::coordinator::{Coordinator, EvalService, SearchAlgo};
use mapperopt::feedback::FeedbackConfig;
use mapperopt::harness::{self, ExpParams};
use mapperopt::mapping::expert_dsl;
use mapperopt::sim::ExecMode;
use mapperopt::util::cli::Args;

fn main() -> ExitCode {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");

    let params = ExpParams {
        iters: args.usize("iters", 10),
        runs: args.usize("runs", 5),
        random_mappers: args.usize("random-mappers", 10),
        seed: args.u64("seed", 0xA11CE),
    };
    let workers = args.usize("workers", 0);
    let service = Arc::new(if workers > 0 {
        EvalService::new(workers, 8 * workers)
    } else {
        EvalService::with_defaults()
    });
    let spec_id = service.spec_id("p100_cluster").expect("preregistered spec");
    let coord = Coordinator::on_service(Arc::clone(&service), spec_id, ExecMode::Serialized);

    match cmd {
        "table1" => {
            harness::table1();
        }
        "table3" => {
            harness::table3(&coord.spec);
        }
        "fig6" => {
            harness::fig6(&coord, params);
        }
        "fig7" => {
            harness::fig7(&coord, params);
        }
        "fig8" => {
            harness::fig8(&coord, params);
        }
        "ablation" => {
            harness::machine_ablation(params);
        }
        "all" => {
            harness::table1();
            harness::table3(&coord.spec);
            harness::fig6(&coord, params);
            harness::fig7(&coord, params);
            harness::fig8(&coord, params);
            print!("\n{}", service.summary());
        }
        "run" => return cmd_run(&coord, &args),
        "optimize" => return cmd_optimize(&coord, &args, params),
        "bench-suite" => {
            for name in apps::ALL_APPS {
                let app = apps::by_name(name).unwrap();
                let fb = coord.evaluate(&app, expert_dsl(name).unwrap());
                println!("{name:10} {}", fb.line());
            }
            print!("\n{}", service.summary());
        }
        "help" => {
            usage();
        }
        _ => {
            usage();
            return ExitCode::from(2);
        }
    }
    ExitCode::SUCCESS
}

fn usage() {
    println!(
        "usage: mapperopt <table1|table3|fig6|fig7|fig8|ablation|all|run|optimize|bench-suite>\n\
         flags: --app NAME --mapper FILE --algo trace|opro \
         --feedback system|explain|full|profile --iters N --runs N --seed S \
         --workers N"
    );
}

fn cmd_run(coord: &Coordinator, args: &Args) -> ExitCode {
    let name = args.str_or("app", "circuit");
    let Some(app) = apps::by_name(name) else {
        eprintln!("unknown app '{name}' (have: {:?})", apps::ALL_APPS);
        return ExitCode::from(2);
    };
    let dsl = match args.get("mapper") {
        Some(path) => match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("cannot read mapper {path}: {e}");
                return ExitCode::from(2);
            }
        },
        None => expert_dsl(name).unwrap().to_string(),
    };
    let fb = coord.evaluate(&app, &dsl);
    println!("{}", fb.line());
    ExitCode::SUCCESS
}

fn cmd_optimize(coord: &Coordinator, args: &Args, p: ExpParams) -> ExitCode {
    let name = args.str_or("app", "circuit");
    let Some(app) = apps::by_name(name) else {
        eprintln!("unknown app '{name}'");
        return ExitCode::from(2);
    };
    let algo = match args.str_or("algo", "trace") {
        "opro" => SearchAlgo::Opro,
        _ => SearchAlgo::Trace,
    };
    let cfg = match args.str_or("feedback", "full") {
        "system" => FeedbackConfig::SYSTEM,
        "explain" => FeedbackConfig::EXPLAIN,
        "profile" => FeedbackConfig::PROFILE,
        _ => FeedbackConfig::FULL,
    };
    let expert = coord.throughput(&app, expert_dsl(name).unwrap());
    println!(
        "optimizing {name} with {} ({}) for {} iterations; expert = {expert:.1}",
        algo.name(),
        cfg.label(),
        p.iters
    );
    let run = coord.run_optimizer(&app, algo, cfg, p.seed, p.iters);
    for r in &run.records {
        println!(
            "iter {:2}  score {:10.1}  best {:10.1}  | {}",
            r.iter,
            r.score,
            r.best_so_far,
            r.feedback.text().replace('\n', " | ")
        );
    }
    if let Some((dsl, score)) = run.best {
        println!(
            "\nbest mapper: {score:.1} ({:.2}x expert)\n---\n{dsl}",
            score / expert
        );
    }
    ExitCode::SUCCESS
}
