//! `mapperopt` — CLI for the mapper-optimization framework.
//!
//! Subcommands:
//!   table1                    — mapper LoC, DSL vs C++ (paper Table 1)
//!   table3                    — strategy->code generation (paper Table 3)
//!   fig6 / fig7 / fig8        — the evaluation figures
//!   all                       — every table and figure in sequence
//!   run --app A [--mapper F]  — execute one app under a mapper (expert
//!                               default), print metrics
//!   optimize --app A [...]    — one optimization campaign, live log
//!   bench-suite               — quick end-to-end status of all benchmarks
//!   serve --addr HOST:PORT    — put the eval service behind a TCP
//!                               listener (the wire protocol of net/)
//!   route --shards A,B,...    — front N `serve` shards behind one
//!                               address with the cache-affinity router
//!   chaos-smoke               — run a remote campaign through the seeded
//!                               fault-injecting chaos proxy and assert it
//!                               is bit-identical to a clean local run
//!   loadtest                  — drive thousands of synthetic campaign
//!                               clients at an eval server (in-process by
//!                               default, --addr for a remote one) and
//!                               report throughput + p50/p99/p999 latency
//!   top --remote HOST:PORT    — fetch a live stats snapshot from a
//!                               `serve` or `route` front and render the
//!                               per-stage latency breakdown (obs::hist)
//!   trace-smoke               — run a traced remote campaign through a
//!                               2-shard routed fleet, assert tracing is
//!                               inert (bit-identical to untraced) and
//!                               that the flight recorder captured a
//!                               span for every traced evaluation
//!
//! Common flags: --iters N --runs N --seed S --algo trace|opro
//!               --feedback system|explain|full --workers N
//!               --remote HOST:PORT (run a subcommand's evaluations
//!               against a `serve` process instead of in-process;
//!               `ablation` excepted — it registers its own sweep
//!               shapes in a dedicated service)
//!               --trace (with --remote: stamp every evaluation with a
//!               trace id so the fleet's flight recorders capture its
//!               full request lifecycle; provably inert — traffic and
//!               scores are unchanged)
//!
//! Without `--remote`, every evaluation flows through one process-wide
//! [`EvalService`] (the serving layer) and the CLI's coordinator is a
//! thin client of it.  With `--remote ADDR`, the same coordinator
//! speaks the wire protocol to a `mapperopt serve` process — campaigns,
//! figures, and bench-suite run unmodified, scores bit-identical — and
//! the `all` / `bench-suite` summaries are fetched from the server.

use std::process::ExitCode;
use std::sync::Arc;
use std::time::Duration;

use mapperopt::apps;
use mapperopt::coordinator::{Coordinator, EvalService, SearchAlgo};
use mapperopt::feedback::FeedbackConfig;
use mapperopt::harness::{self, ExpParams};
use mapperopt::machine::MachineSpec;
use mapperopt::mapping::expert_dsl;
use mapperopt::net::{
    loadtest, ChaosConfig, ChaosProxy, EvalRouter, EvalServer, LoadtestConfig,
    RemoteEvalClient, RetryPolicy, ServerConfig,
};
use mapperopt::obs::{fmt_ns, FlightRecorder, SpanRecord, Stage, SPAN_OK};
use mapperopt::sim::ExecMode;
use mapperopt::util::cli::Args;

fn main() -> ExitCode {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");

    let params = ExpParams {
        iters: args.usize("iters", 10),
        runs: args.usize("runs", 5),
        random_mappers: args.usize("random-mappers", 10),
        seed: args.u64("seed", 0xA11CE),
    };
    let workers = args.usize("workers", 0);

    if cmd == "serve" {
        return cmd_serve(&args, workers);
    }
    if cmd == "route" {
        return cmd_route(&args);
    }
    if cmd == "chaos-smoke" {
        return cmd_chaos_smoke(&args, workers);
    }
    if cmd == "loadtest" {
        return cmd_loadtest(&args, workers);
    }
    if cmd == "top" {
        return cmd_top(&args);
    }
    if cmd == "trace-smoke" {
        return cmd_trace_smoke(&args, workers);
    }

    let coord = match args.get("remote") {
        Some(addr) => {
            match Coordinator::remote(addr, "p100_cluster", ExecMode::Serialized) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("{e}");
                    return ExitCode::from(2);
                }
            }
        }
        None => {
            let service = service_for(workers);
            let spec_id = service.spec_id("p100_cluster").expect("preregistered spec");
            Coordinator::on_service(service, spec_id, ExecMode::Serialized)
        }
    };

    // --trace: stamp every remote evaluation with a client trace id so
    // the fleet's flight recorders capture its full request lifecycle
    // (dump with `mapperopt top --remote ADDR` or Request::TraceDump);
    // inert — the traffic shape and every score are unchanged
    if args.flag("trace") {
        match coord.remote_client() {
            Some(client) => client.set_tracing(true),
            None => eprintln!(
                "--trace needs --remote (in-process evaluations have no wire \
                 to trace); ignoring"
            ),
        }
    }

    match cmd {
        "table1" => {
            harness::table1();
        }
        "table3" => {
            harness::table3(&coord.spec);
        }
        "fig6" => {
            harness::fig6(&coord, params);
        }
        "fig7" => {
            harness::fig7(&coord, params);
        }
        "fig8" => {
            harness::fig8(&coord, params);
        }
        "ablation" => {
            if args.get("remote").is_some() {
                // the sweep registers its own generated machine shapes in
                // a dedicated multi-spec service; silently running it
                // in-process would make --remote a lie
                eprintln!(
                    "ablation drives its own multi-spec service and does not \
                     support --remote"
                );
                return ExitCode::from(2);
            }
            harness::machine_ablation(params);
        }
        "all" => {
            harness::table1();
            harness::table3(&coord.spec);
            harness::fig6(&coord, params);
            harness::fig7(&coord, params);
            harness::fig8(&coord, params);
            print!("\n{}", coord.summary());
        }
        "run" => return cmd_run(&coord, &args),
        "optimize" => return cmd_optimize(&coord, &args, params),
        "bench-suite" => {
            for name in apps::ALL_APPS {
                let app = apps::by_name(name).unwrap();
                let fb = coord.evaluate(&app, expert_dsl(name).unwrap());
                println!("{name:10} {}", fb.line());
            }
            print!("\n{}", coord.summary());
        }
        "help" => {
            usage();
        }
        _ => {
            usage();
            return ExitCode::from(2);
        }
    }
    ExitCode::SUCCESS
}

fn usage() {
    println!(
        "usage: mapperopt <table1|table3|fig6|fig7|fig8|ablation|all|run|optimize|bench-suite|serve|route|chaos-smoke|loadtest|top|trace-smoke>\n\
         flags: --app NAME --mapper FILE --algo trace|opro \
         --feedback system|explain|full|profile --iters N --runs N --seed S \
         --workers N --remote HOST:PORT --addr HOST:PORT (serve/route/loadtest) \
         --trace (with --remote: trace-id-stamp every evaluation; inert)\n\
         route: --shards A,B,... (comma-separated serve addresses; each is \
         ping-probed) --addr HOST:PORT (front, default 127.0.0.1:9378)\n\
         loadtest: --clients N (1000) --duration SECS (10) --rate R (open loop; \
         default closed) --pipeline K (1) --batch K (1) --distinct N (8) \
         --generators N (auto) --json --router (fleet sweep; --shards 1,2,4 \
         shard *counts*, in-process)\n\
         top: --remote HOST:PORT (serve or route front) --watch SECS (refresh \
         loop; default one-shot) — live per-stage latency breakdown\n\
         env:   MAPPEROPT_RETRY_BUDGET    remote client transmission attempts per request (default 4)\n\
         \x20      MAPPEROPT_QUEUE_HIGH_WATER eval queue depth that starts shedding lowest-priority\n\
         \x20                                 work with Overloaded responses (default: queue capacity)\n\
         \x20      MAPPEROPT_IO_THREADS       server I/O threads multiplexing all connections\n\
         \x20                                 (default min(4, cores))\n\
         \x20      MAPPEROPT_MAX_CONNECTIONS  server concurrent-connection cap; dials beyond it\n\
         \x20                                 are counted and refused with Overloaded (default 4096)\n\
         \x20      MAPPEROPT_CONN_DEADLINE_S  server-side idle-connection reap deadline in seconds,\n\
         \x20                                 answered as a retryable Deadline error (default 300,\n\
         \x20                                 0 disables)\n\
         \x20      MAPPEROPT_WIRE_BATCH       client-side EvalBatch frame coalescing; 0 disables\n\
         \x20                                 (default on, bit-identical either way)\n\
         \x20      MAPPEROPT_SERVE_DEADLINE_S chaos-smoke/serve-smoke/loadtest self-kill deadline\n\
         \x20                                 in seconds (default 180)\n\
         \x20      MAPPEROPT_SHARDS           default --shards list for `route` (comma-separated\n\
         \x20                                 serve addresses)\n\
         \x20      MAPPEROPT_ROUTER_ADDR      default front address for `route` (127.0.0.1:9378)\n\
         \x20      MAPPEROPT_TRACE            client-side: stamp every request with a trace id\n\
         \x20                                 (same switch as --trace; inert; 0/empty disables)\n\
         \x20      MAPPEROPT_TRACE_RING       flight-recorder ring capacity in spans per process\n\
         \x20                                 (default 1024, 0 disables recording)\n\
         \x20      MAPPEROPT_TRACE_SLOW_MS    untraced requests slower than this are still\n\
         \x20                                 recorded as forensic spans (default 1000, 0 disables)"
    );
}

/// `mapperopt loadtest [--clients N] [--duration SECS] [--rate R]
/// [--pipeline K] [--batch K] [--distinct N] [--addr HOST:PORT]
/// [--json]`: the multiplexed-serving load harness (see
/// `net::loadtest`).  Without `--addr` it boots an in-process server
/// sized for the client count; `--json` prints one machine-readable
/// object (the `BENCH_serve.json` line) instead of the human report.
fn cmd_loadtest(args: &Args, workers: usize) -> ExitCode {
    let deadline_s = std::env::var("MAPPEROPT_SERVE_DEADLINE_S")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(180);
    std::thread::spawn(move || {
        std::thread::sleep(Duration::from_secs(deadline_s));
        eprintln!("loadtest: exceeded the {deadline_s}s deadline; wedged");
        std::process::exit(124);
    });

    let cfg = LoadtestConfig {
        clients: args.usize("clients", 1000),
        duration: Duration::from_secs(args.u64("duration", 10)),
        rate: args.get("rate").and_then(|v| v.parse::<f64>().ok()),
        pipeline: args.usize("pipeline", 1),
        batch: args.usize("batch", 1),
        distinct: args.usize("distinct", 8),
        generators: args.usize("generators", 0),
    };

    // --router: the fleet sweep — boot in-process shard fleets of each
    // requested size behind an EvalRouter and drive the identical load
    // at each (plus a bare-server baseline); see net::loadtest::run_fleet
    if args.flag("router") {
        let counts: Vec<usize> = args
            .get("shards")
            .map(String::as_str)
            .unwrap_or("1,2,4")
            .split(',')
            .filter_map(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
            .collect();
        if counts.is_empty() {
            eprintln!("loadtest: --shards wants a comma-separated count list");
            return ExitCode::from(2);
        }
        if !args.flag("json") {
            println!(
                "loadtest: fleet sweep over {counts:?} shard(s), {} clients, \
                 {:?} window each",
                cfg.clients, cfg.duration
            );
        }
        let fleet = match loadtest::run_fleet(&counts, &cfg, workers) {
            Ok(f) => f,
            Err(e) => {
                eprintln!("loadtest: fleet sweep failed to boot: {e}");
                return ExitCode::from(2);
            }
        };
        if args.flag("json") {
            println!("{}", fleet.json());
        } else {
            print!("{}", fleet.text());
        }
        if fleet.healthy() {
            return ExitCode::SUCCESS;
        }
        eprintln!("loadtest: FAILED — a sweep point served no healthy load");
        return ExitCode::FAILURE;
    }

    // without --addr, boot an in-process server sized so the requested
    // client count fits under the connection cap (the refusal path is
    // exercised deliberately by pointing --clients above
    // MAPPEROPT_MAX_CONNECTIONS at an external --addr server)
    let (server, addr) = match args.get("addr") {
        Some(a) => match a.parse() {
            Ok(sa) => (None, sa),
            Err(e) => {
                eprintln!("loadtest: bad --addr {a}: {e}");
                return ExitCode::from(2);
            }
        },
        None => {
            let service = service_for(workers);
            let sc = ServerConfig {
                max_connections: cfg.clients + 64,
                ..ServerConfig::default()
            };
            match EvalServer::bind_with("127.0.0.1:0", service, sc) {
                Ok(s) => {
                    let a = s.addr();
                    (Some(s), a)
                }
                Err(e) => {
                    eprintln!("loadtest: cannot bind eval server: {e}");
                    return ExitCode::from(2);
                }
            }
        }
    };

    if !args.flag("json") {
        println!(
            "loadtest: {} clients, {:?} window, {} loop{}{}",
            cfg.clients,
            cfg.duration,
            if cfg.rate.is_some() { "open" } else { "closed" },
            cfg.rate.map(|r| format!(" @ {r} req/s")).unwrap_or_default(),
            if cfg.batch > 1 {
                format!(", batch {}", cfg.batch)
            } else {
                String::new()
            },
        );
    }
    let report = loadtest::run(addr, &cfg);
    if let Some(s) = server {
        s.shutdown();
    }
    if args.flag("json") {
        println!("{}", report.json());
    } else {
        print!("{}", report.text());
    }

    // gate for CI: the run must actually have served load — every
    // client answered (sheds are fine; they are the protection working)
    // and nothing classified as a hard error
    let healthy = report.completed > 0
        && report.errors == 0
        && report.connected >= cfg.clients - cfg.clients / 10;
    if healthy {
        ExitCode::SUCCESS
    } else {
        eprintln!(
            "loadtest: FAILED — {}/{} clients connected, {} completed, {} errors",
            report.connected, cfg.clients, report.completed, report.errors
        );
        ExitCode::FAILURE
    }
}

/// The process-wide service: explicit worker count (queue sized to
/// match) or host-derived defaults — one policy for the in-process and
/// `serve` paths alike.
fn service_for(workers: usize) -> Arc<EvalService> {
    Arc::new(if workers > 0 {
        EvalService::new(workers, 8 * workers)
    } else {
        EvalService::with_defaults()
    })
}

/// `mapperopt serve --addr HOST:PORT [--workers N]`: one process-wide
/// [`EvalService`] behind a TCP listener, serving every connected
/// campaign process until killed.
fn cmd_serve(args: &Args, workers: usize) -> ExitCode {
    let addr = args.str_or("addr", "127.0.0.1:9377");
    let service = service_for(workers);
    match EvalServer::bind(addr, service) {
        Ok(server) => {
            println!(
                "eval service listening on {} (p100_cluster + small preregistered; \
                 Ctrl-C to stop)",
                server.addr()
            );
            server.join();
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("cannot bind {addr}: {e}");
            ExitCode::from(2)
        }
    }
}

/// `mapperopt route --shards A,B,... [--addr HOST:PORT]`: front N
/// running `serve` shards behind one address with the cache-affinity
/// [`EvalRouter`] (see `net::router`).  `--shards` (or
/// `MAPPEROPT_SHARDS`) is a comma-separated list of shard addresses,
/// each probed at bind; `--addr` (or `MAPPEROPT_ROUTER_ADDR`) is the
/// front address, default `127.0.0.1:9378`.
fn cmd_route(args: &Args) -> ExitCode {
    let env_shards = std::env::var("MAPPEROPT_SHARDS").ok();
    let shards: Vec<String> = args
        .get("shards")
        .map(String::as_str)
        .or(env_shards.as_deref())
        .unwrap_or("")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect();
    if shards.is_empty() {
        eprintln!(
            "route: no shards — pass --shards A,B,... or set MAPPEROPT_SHARDS"
        );
        return ExitCode::from(2);
    }
    let env_addr = std::env::var("MAPPEROPT_ROUTER_ADDR").ok();
    let addr = args
        .get("addr")
        .map(String::as_str)
        .or(env_addr.as_deref())
        .unwrap_or("127.0.0.1:9378");
    match EvalRouter::bind(addr, &shards) {
        Ok(router) => {
            println!(
                "eval router listening on {} fronting {} shard(s): {} \
                 (Ctrl-C to stop)",
                router.addr(),
                shards.len(),
                shards.join(", ")
            );
            router.join();
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("cannot front the fleet on {addr}: {e}");
            ExitCode::from(2)
        }
    }
}

/// `mapperopt top --remote HOST:PORT [--watch SECS]`: fetch a live
/// stats snapshot from a `serve` shard or `route` front and render the
/// per-stage latency breakdown riding its histogram tail (count /
/// p50 / p99 / max per [`Stage`]).  Against a router front the
/// snapshot is the fleet aggregate — shard histograms merged
/// bucket-wise by `StatsSnapshot::aggregate_fleet`, the router's own
/// route/upstream stages on top.  `--watch SECS` refreshes in a loop
/// until killed; the default is one shot.
fn cmd_top(args: &Args) -> ExitCode {
    let Some(addr) = args.get("remote").or_else(|| args.get("addr")) else {
        eprintln!("top: which server? pass --remote HOST:PORT");
        return ExitCode::from(2);
    };
    let client = match RemoteEvalClient::connect(addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("top: cannot connect to {addr}: {e}");
            return ExitCode::from(2);
        }
    };
    let watch = args.u64("watch", 0);
    loop {
        let snap = match client.stats() {
            Ok(s) => s,
            Err(e) => {
                eprintln!("top: stats fetch failed: {e}");
                return ExitCode::FAILURE;
            }
        };
        println!(
            "{addr}: {} evals ({} cache hits, {} decision), {} completed, \
             {} shed",
            snap.evals,
            snap.cache_hits,
            snap.decision_hits,
            snap.completed,
            snap.shed_requests
        );
        if snap.stage_hists.is_empty() {
            println!("  (no stage latency yet — nothing served since boot)");
        } else {
            println!(
                "  {:<10} {:>10} {:>9} {:>9} {:>9}",
                "stage", "count", "p50", "p99", "max"
            );
            for sh in &snap.stage_hists {
                println!(
                    "  {:<10} {:>10} {:>9} {:>9} {:>9}",
                    Stage::name_of(sh.stage),
                    sh.hist.count(),
                    fmt_ns(sh.hist.percentile(50.0)),
                    fmt_ns(sh.hist.percentile(99.0)),
                    fmt_ns(sh.hist.max()),
                );
            }
        }
        if watch == 0 {
            return ExitCode::SUCCESS;
        }
        std::thread::sleep(Duration::from_secs(watch));
        println!();
    }
}

/// Dump a flight recorder onto stderr next to a smoke-test failure:
/// the forensic spans (errors, sheds, slow requests, traced ids) the
/// serving side retained around the failure window.
fn print_flight_recorder(label: &str, spans: &[SpanRecord]) {
    eprint!("{label}: {}", FlightRecorder::render(spans));
}

/// `mapperopt chaos-smoke`: the fault-tolerance acceptance drive.  Runs
/// one seeded campaign clean and in-process, then the same campaign
/// through a [`ChaosProxy`] injecting delays, corruption, truncation,
/// and resets, and requires (a) bit-identical trajectories and best
/// scores and (b) observed `retries > 0` and `reconnects > 0` — i.e.
/// the faults actually fired and the retry machinery actually hid them.
/// A watchdog thread enforces `MAPPEROPT_SERVE_DEADLINE_S` (default
/// 180s) so a wedged run fails CI instead of hanging it.
fn cmd_chaos_smoke(args: &Args, workers: usize) -> ExitCode {
    let deadline_s = std::env::var("MAPPEROPT_SERVE_DEADLINE_S")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(180);
    std::thread::spawn(move || {
        std::thread::sleep(Duration::from_secs(deadline_s));
        eprintln!("chaos-smoke: exceeded the {deadline_s}s deadline; wedged");
        std::process::exit(124);
    });

    let (app, algo, cfg) = ("cannon", SearchAlgo::Trace, FeedbackConfig::FULL);
    let base_seed = args.u64("seed", 5);
    let runs = args.usize("runs", 2);
    let iters = args.usize("iters", 6);

    println!(
        "chaos-smoke: clean in-process reference ({app}, {runs} runs x {iters} iters)"
    );
    let local = Coordinator::new(MachineSpec::p100_cluster());
    let reference = match local.run_many(app, algo, cfg, base_seed, runs, iters) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("chaos-smoke: reference campaign failed: {e}");
            return ExitCode::from(2);
        }
    };

    let service = service_for(workers);
    let server = match EvalServer::bind("127.0.0.1:0", Arc::clone(&service)) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("chaos-smoke: cannot bind eval server: {e}");
            return ExitCode::from(2);
        }
    };
    let backend = server.addr();

    // sweep a few proxy seeds: each is fully deterministic, and the
    // sweep makes "a schedule that only drew harmless delays" a
    // non-issue — every pass must still be bit-identical, and the smoke
    // only demands that *some* pass exercised retry and reconnect
    let (mut retries, mut reconnects, mut faults) = (0u64, 0u64, 0u64);
    for (pass, chaos_seed) in
        [0xC4A0_5EEDu64, 0xC4A0_5EEE, 0xC4A0_5EEF].into_iter().enumerate()
    {
        let chaos = ChaosConfig {
            seed: chaos_seed,
            delay_weight: 1,
            corrupt_weight: 2,
            truncate_weight: 1,
            reset_weight: 2,
            blackhole_weight: 0,
            ..ChaosConfig::default()
        };
        let proxy = match ChaosProxy::bind("127.0.0.1:0", backend, chaos) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("chaos-smoke: cannot bind chaos proxy: {e}");
                return ExitCode::from(2);
            }
        };
        let policy = RetryPolicy {
            deadline: Duration::from_secs(20),
            budget: 16,
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(250),
            seed: chaos_seed,
        };
        let front = proxy.addr().to_string();
        let coord = match Coordinator::remote_with(
            &front,
            "p100_cluster",
            ExecMode::Serialized,
            policy,
        ) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("chaos-smoke: cannot connect through the proxy: {e}");
                return ExitCode::FAILURE;
            }
        };
        let chaotic = match coord.run_many(app, algo, cfg, base_seed, runs, iters)
        {
            Ok(r) => r,
            Err(e) => {
                eprintln!("chaos-smoke: campaign under faults failed: {e}");
                return ExitCode::FAILURE;
            }
        };
        if chaotic.len() != reference.len() {
            eprintln!(
                "chaos-smoke: FAILED — {} runs came back, expected {}",
                chaotic.len(),
                reference.len()
            );
            print_flight_recorder("chaos-smoke", &service.trace_dump());
            return ExitCode::FAILURE;
        }
        for (c, l) in chaotic.iter().zip(&reference) {
            let same_best = c.best.as_ref().map(|(_, s)| s.to_bits())
                == l.best.as_ref().map(|(_, s)| s.to_bits());
            if c.trajectory() != l.trajectory() || !same_best {
                eprintln!(
                    "chaos-smoke: FAILED — seed {} diverged under faults:\n  \
                     faulty: {:?}\n  clean:  {:?}",
                    c.seed,
                    c.trajectory(),
                    l.trajectory()
                );
                print_flight_recorder("chaos-smoke", &service.trace_dump());
                return ExitCode::FAILURE;
            }
        }
        let client = coord.remote_client().expect("remote backend");
        retries += client.retries();
        reconnects += client.reconnects();
        let ps = proxy.stats();
        faults += ps.faults();
        println!(
            "chaos-smoke: pass {} (chaos seed {chaos_seed:#x}): {} faults \
             ({} delays, {} corruptions, {} truncations, {} resets) over {} \
             connections; {} retries, {} reconnects; bit-identical",
            pass + 1,
            ps.faults(),
            ps.delays,
            ps.corruptions,
            ps.truncations,
            ps.resets,
            ps.connections,
            client.retries(),
            client.reconnects(),
        );
        drop(coord);
        proxy.shutdown();
        if retries > 0 && reconnects > 0 {
            break;
        }
    }
    server.shutdown();

    if retries == 0 || reconnects == 0 {
        eprintln!(
            "chaos-smoke: FAILED — expected retries > 0 and reconnects > 0, \
             got {retries} retries / {reconnects} reconnects ({faults} faults)"
        );
        print_flight_recorder("chaos-smoke", &service.trace_dump());
        return ExitCode::FAILURE;
    }
    println!(
        "chaos-smoke: OK — remote-under-faults == clean local, bit-identical; \
         {retries} retries, {reconnects} reconnects, {faults} faults injected"
    );
    ExitCode::SUCCESS
}

/// `mapperopt trace-smoke`: the observability acceptance drive.  Boots
/// two in-process eval shards behind the cache-affinity router, runs
/// one seeded campaign untraced through the front and then the
/// identical campaign traced, and requires:
///
///  (a) **inertness** — traced trajectories and best scores are
///      bit-identical to the untraced pass (a trace id changes no
///      routing decision, no cache key, no score);
///  (b) **coverage** — the fleet's flight recorders (fetched with one
///      `Request::TraceDump` fanned out by the router) hold a span for
///      every trace id the traced campaign stamped: ids are issued
///      contiguously from 1, so the distinct ids recovered must be
///      exactly `1..=N` — a gap is a lost span;
///  (c) **consistency** — every span carries at least one stage and
///      its per-stage durations sum to at most the recorded wall time,
///      and no traced span resolved with a non-OK outcome.
///
/// A watchdog thread enforces `MAPPEROPT_SERVE_DEADLINE_S` (default
/// 180s) so a wedged run fails CI instead of hanging it.
fn cmd_trace_smoke(args: &Args, workers: usize) -> ExitCode {
    let deadline_s = std::env::var("MAPPEROPT_SERVE_DEADLINE_S")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(180);
    std::thread::spawn(move || {
        std::thread::sleep(Duration::from_secs(deadline_s));
        eprintln!("trace-smoke: exceeded the {deadline_s}s deadline; wedged");
        std::process::exit(124);
    });

    let (app, algo, cfg) = ("cannon", SearchAlgo::Trace, FeedbackConfig::FULL);
    let base_seed = args.u64("seed", 7);
    let runs = args.usize("runs", 2);
    let iters = args.usize("iters", 6);

    // two shards behind the router, all in-process on loopback
    let mut servers = Vec::new();
    let mut shard_addrs = Vec::new();
    for _ in 0..2 {
        match EvalServer::bind("127.0.0.1:0", service_for(workers)) {
            Ok(s) => {
                shard_addrs.push(s.addr().to_string());
                servers.push(s);
            }
            Err(e) => {
                eprintln!("trace-smoke: cannot bind eval shard: {e}");
                return ExitCode::from(2);
            }
        }
    }
    let router = match EvalRouter::bind("127.0.0.1:0", &shard_addrs) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("trace-smoke: cannot bind router: {e}");
            return ExitCode::from(2);
        }
    };
    let front = router.addr().to_string();
    println!(
        "trace-smoke: 2 shards behind {front} ({app}, {runs} runs x {iters} \
         iters), untraced reference first"
    );

    let reference = {
        let coord =
            match Coordinator::remote(&front, "p100_cluster", ExecMode::Serialized)
            {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("trace-smoke: cannot connect untraced: {e}");
                    return ExitCode::FAILURE;
                }
            };
        match coord.run_many(app, algo, cfg, base_seed, runs, iters) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("trace-smoke: untraced campaign failed: {e}");
                return ExitCode::FAILURE;
            }
        }
    };

    // the traced pass: a fresh client (its trace-id sequence starts at
    // 1), the identical campaign, every request stamped
    let coord =
        match Coordinator::remote(&front, "p100_cluster", ExecMode::Serialized) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("trace-smoke: cannot connect traced: {e}");
                return ExitCode::FAILURE;
            }
        };
    let client = Arc::clone(coord.remote_client().expect("remote backend"));
    client.set_tracing(true);
    let traced = match coord.run_many(app, algo, cfg, base_seed, runs, iters) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("trace-smoke: traced campaign failed: {e}");
            return ExitCode::FAILURE;
        }
    };

    // (a) inertness: bit-identical to the untraced pass
    if traced.len() != reference.len() {
        eprintln!(
            "trace-smoke: FAILED — {} traced runs came back, expected {}",
            traced.len(),
            reference.len()
        );
        return ExitCode::FAILURE;
    }
    for (t, r) in traced.iter().zip(&reference) {
        let same_best = t.best.as_ref().map(|(_, s)| s.to_bits())
            == r.best.as_ref().map(|(_, s)| s.to_bits());
        if t.trajectory() != r.trajectory() || !same_best {
            eprintln!(
                "trace-smoke: FAILED — tracing is not inert; seed {} \
                 diverged:\n  traced:   {:?}\n  untraced: {:?}",
                t.seed,
                t.trajectory(),
                r.trajectory()
            );
            return ExitCode::FAILURE;
        }
    }

    // (b) + (c): pull every flight recorder through the front and audit
    let spans = match client.trace_dump() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("trace-smoke: trace dump fetch failed: {e}");
            return ExitCode::FAILURE;
        }
    };
    let mut lows: Vec<u64> = spans
        .iter()
        .filter(|s| s.trace_id != 0)
        .map(|s| s.trace_id & 0xffff_ffff)
        .collect();
    lows.sort_unstable();
    lows.dedup();
    let issued = lows.last().copied().unwrap_or(0);
    if issued < runs as u64
        || lows.len() as u64 != issued
        || lows.first() != Some(&1)
    {
        eprintln!(
            "trace-smoke: FAILED — {} distinct traced span ids recovered but \
             ids 1..={issued} were issued (a gap is a lost span)",
            lows.len()
        );
        print_flight_recorder("trace-smoke", &spans);
        return ExitCode::FAILURE;
    }
    for s in &spans {
        let stage_sum: u64 =
            s.stages.iter().fold(0, |a, x| a.saturating_add(x.dur_ns));
        if stage_sum > s.total_ns || (s.trace_id != 0 && s.stages.is_empty()) {
            eprintln!(
                "trace-smoke: FAILED — inconsistent span (stage sum \
                 {stage_sum}ns vs wall {}ns):\n  {}",
                s.total_ns,
                s.render()
            );
            return ExitCode::FAILURE;
        }
        if s.trace_id != 0 && s.outcome != SPAN_OK {
            eprintln!(
                "trace-smoke: FAILED — traced span resolved non-OK:\n  {}",
                s.render()
            );
            return ExitCode::FAILURE;
        }
    }

    drop(coord);
    router.shutdown();
    for s in servers {
        s.shutdown();
    }
    println!(
        "trace-smoke: OK — traced == untraced bit-identical; {} spans cover \
         all {issued} traced evaluations across the fleet's recorders",
        spans.len()
    );
    ExitCode::SUCCESS
}

fn cmd_run(coord: &Coordinator, args: &Args) -> ExitCode {
    let name = args.str_or("app", "circuit");
    let Some(app) = apps::by_name(name) else {
        eprintln!("unknown app '{name}' (have: {:?})", apps::ALL_APPS);
        return ExitCode::from(2);
    };
    let dsl = match args.get("mapper") {
        Some(path) => match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("cannot read mapper {path}: {e}");
                return ExitCode::from(2);
            }
        },
        None => expert_dsl(name).unwrap().to_string(),
    };
    let fb = coord.evaluate(&app, &dsl);
    println!("{}", fb.line());
    ExitCode::SUCCESS
}

fn cmd_optimize(coord: &Coordinator, args: &Args, p: ExpParams) -> ExitCode {
    let name = args.str_or("app", "circuit");
    let Some(app) = apps::by_name(name) else {
        eprintln!("unknown app '{name}'");
        return ExitCode::from(2);
    };
    let algo = match args.str_or("algo", "trace") {
        "opro" => SearchAlgo::Opro,
        _ => SearchAlgo::Trace,
    };
    let cfg = match args.str_or("feedback", "full") {
        "system" => FeedbackConfig::SYSTEM,
        "explain" => FeedbackConfig::EXPLAIN,
        "profile" => FeedbackConfig::PROFILE,
        _ => FeedbackConfig::FULL,
    };
    let expert = coord.throughput(&app, expert_dsl(name).unwrap());
    println!(
        "optimizing {name} with {} ({}) for {} iterations; expert = {expert:.1}",
        algo.name(),
        cfg.label(),
        p.iters
    );
    let run = coord.run_optimizer(&app, algo, cfg, p.seed, p.iters);
    for r in &run.records {
        println!(
            "iter {:2}  score {:10.1}  best {:10.1}  | {}",
            r.iter,
            r.score,
            r.best_so_far,
            r.feedback.text().replace('\n', " | ")
        );
    }
    if run.proposer_dupes > 0 {
        println!(
            "({} semantically duplicate proposals served from the run's memo)",
            run.proposer_dupes
        );
    }
    if let Some((dsl, score)) = run.best {
        println!(
            "\nbest mapper: {score:.1} ({:.2}x expert)\n---\n{dsl}",
            score / expert
        );
    }
    ExitCode::SUCCESS
}
