//! Request-lifecycle tracing: client-stamped ids, per-request span
//! records, and the per-eval telemetry rider.
//!
//! A trace id is stamped once, client-side, and propagated unchanged
//! through router and shard as a *trailing optional* wire field (elided
//! when zero, so untraced traffic is byte-identical to older peers).
//! Every layer that observes the request appends stage timings relative
//! to its own span start — timestamps are monotonic `Instant` deltas,
//! never wall clocks — and the finished [`SpanRecord`] lands in the
//! layer's [`FlightRecorder`](super::FlightRecorder).
//!
//! Tracing is **inert** by construction: ids never enter cache keys,
//! scheduling decisions, or feedback values, so a traced campaign is
//! bit-identical to an untraced one (a property test holds this).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use super::hist::Stage;

/// Span outcome: the request served normally.
pub const SPAN_OK: u8 = 0;
/// Span outcome: the request resolved with a classified error.
pub const SPAN_ERROR: u8 = 1;
/// Span outcome: admission control shed the request.
pub const SPAN_SHED: u8 = 2;
/// Span outcome: the router re-routed or bounced it off a dead shard.
pub const SPAN_REROUTED: u8 = 3;

pub fn outcome_name(outcome: u8) -> &'static str {
    match outcome {
        SPAN_OK => "ok",
        SPAN_ERROR => "error",
        SPAN_SHED => "shed",
        SPAN_REROUTED => "rerouted",
        _ => "unknown",
    }
}

/// Which serving path answered an evaluation.  Codes are wire stable
/// (they ride span records and the telemetry tail of traced feedback).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum CachePath {
    /// Not classified (non-eval requests, or decoded from older peers).
    Unknown = 0,
    /// Text-level feedback-cache hit.
    Hit = 1,
    /// Joined a concurrent identical in-flight evaluation.
    Follower = 2,
    /// Semantic decision-cache hit.
    Decision = 3,
    /// Delta splice against the incumbent recording.
    Splice = 4,
    /// Cold: full simulation (or compile / resolution error).
    Cold = 5,
    /// Shed by admission control before evaluating.
    Shed = 6,
}

impl CachePath {
    pub const COUNT: usize = 7;

    pub const ALL: [CachePath; CachePath::COUNT] = [
        CachePath::Unknown,
        CachePath::Hit,
        CachePath::Follower,
        CachePath::Decision,
        CachePath::Splice,
        CachePath::Cold,
        CachePath::Shed,
    ];

    pub fn from_code(code: u8) -> CachePath {
        CachePath::ALL.get(code as usize).copied().unwrap_or(CachePath::Unknown)
    }

    pub fn name(self) -> &'static str {
        match self {
            CachePath::Unknown => "unknown",
            CachePath::Hit => "hit",
            CachePath::Follower => "follower",
            CachePath::Decision => "decision",
            CachePath::Splice => "splice",
            CachePath::Cold => "cold",
            CachePath::Shed => "shed",
        }
    }
}

/// Per-eval fabric telemetry riding inside
/// [`SystemFeedback`](crate::feedback::SystemFeedback): where the
/// serving time went for *this* serving of the request, so an optimizer
/// (or a human) can tell "the mapper is slow" from "the fabric was
/// congested".  Never part of feedback equality or caching — two
/// evaluations of the same mapper are the same result regardless of
/// queue depth.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct EvalTelemetry {
    /// Time queued before a worker picked the job up (0 on synchronous
    /// and cache-hit paths).
    pub queue_ns: u64,
    /// Which serving path answered (a [`CachePath`] code).
    pub cache_path: u8,
    /// Pure simulation time of this serving (0 when answered from
    /// cache).
    pub sim_ns: u64,
}

impl EvalTelemetry {
    pub fn path(&self) -> CachePath {
        CachePath::from_code(self.cache_path)
    }
}

/// One stage's timing inside a [`SpanRecord`]: offset from the span
/// start and duration, both monotonic nanoseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageSpan {
    /// Raw [`Stage`] code (kept raw for forward compatibility).
    pub stage: u8,
    pub start_ns: u64,
    pub dur_ns: u64,
}

/// One request's recorded lifecycle: which stages it passed through,
/// which cache path answered it, how it ended, and the serving wall
/// time.  Stage durations are disjoint measurements of the same span,
/// so they sum to at most `total_ns` (modulo measurement jitter).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct SpanRecord {
    /// Client-stamped id (0 for untraced forensic spans).
    pub trace_id: u64,
    /// A [`CachePath`] code.
    pub cache_path: u8,
    /// One of [`SPAN_OK`] / [`SPAN_ERROR`] / [`SPAN_SHED`] /
    /// [`SPAN_REROUTED`].
    pub outcome: u8,
    /// Span wall time (first observation → resolution).
    pub total_ns: u64,
    pub stages: Vec<StageSpan>,
}

impl SpanRecord {
    /// One-line render (the flight-recorder dump format).
    pub fn render(&self) -> String {
        use super::hist::fmt_ns;
        let mut line = format!(
            "trace {:016x} {:<8} path {:<8} total {:>9}",
            self.trace_id,
            outcome_name(self.outcome),
            CachePath::from_code(self.cache_path).name(),
            fmt_ns(self.total_ns),
        );
        for s in &self.stages {
            line.push_str(&format!(
                "  {}@+{}/{}",
                Stage::name_of(s.stage),
                fmt_ns(s.start_ns),
                fmt_ns(s.dur_ns),
            ));
        }
        line
    }
}

/// Builds one [`SpanRecord`] against a monotonic span epoch.  Stage
/// offsets are computed from the builder's `t0`, so timestamps are
/// monotone regardless of which thread observes which stage.
pub struct SpanBuilder {
    trace_id: u64,
    t0: Instant,
    cache_path: CachePath,
    outcome: u8,
    stages: Vec<StageSpan>,
}

impl SpanBuilder {
    /// Open a span now; `trace_id` may be 0 (forensic-only span).
    pub fn begin(trace_id: u64) -> SpanBuilder {
        SpanBuilder::begin_at(trace_id, Instant::now())
    }

    /// Open a span whose epoch is an already-taken instant (e.g. the
    /// moment the request was enqueued), so earlier stages measured
    /// against that instant stay inside the span's wall time.
    pub fn begin_at(trace_id: u64, t0: Instant) -> SpanBuilder {
        SpanBuilder {
            trace_id,
            t0,
            cache_path: CachePath::Unknown,
            outcome: SPAN_OK,
            stages: Vec::new(),
        }
    }

    pub fn trace_id(&self) -> u64 {
        self.trace_id
    }

    /// The span epoch (lets callers measure a stage that started at
    /// span open).
    pub fn t0(&self) -> Instant {
        self.t0
    }

    /// Record a stage that started at `started` (clamped to the span
    /// epoch) and ran `dur_ns`.
    pub fn stage(&mut self, stage: Stage, started: Instant, dur_ns: u64) {
        let start_ns = started.saturating_duration_since(self.t0).as_nanos() as u64;
        self.stages.push(StageSpan { stage: stage as u8, start_ns, dur_ns });
    }

    /// Record a stage that started `start_ns` after the span epoch.
    pub fn stage_at(&mut self, stage: Stage, start_ns: u64, dur_ns: u64) {
        self.stages.push(StageSpan { stage: stage as u8, start_ns, dur_ns });
    }

    pub fn cache_path(&mut self, path: CachePath) {
        self.cache_path = path;
    }

    pub fn outcome(&mut self, outcome: u8) {
        self.outcome = outcome;
    }

    /// Close the span: total wall time is the elapsed monotonic time
    /// since the span epoch, raised to the stage-duration sum if
    /// measurement jitter ever put a stage past it — so per-stage
    /// durations always sum to within the recorded wall time.
    pub fn finish(self) -> SpanRecord {
        let stage_sum =
            self.stages.iter().fold(0u64, |a, s| a.saturating_add(s.dur_ns));
        let total_ns = (self.t0.elapsed().as_nanos() as u64).max(stage_sum);
        SpanRecord {
            trace_id: self.trace_id,
            cache_path: self.cache_path as u8,
            outcome: self.outcome,
            total_ns,
            stages: self.stages,
        }
    }
}

/// Client-side trace-id allocator: process-unique high bits, one
/// atomic counter for the low bits, never yields 0 (0 means untraced
/// on the wire).
pub struct TraceIdGen {
    hi: u64,
    seq: AtomicU64,
}

impl Default for TraceIdGen {
    fn default() -> TraceIdGen {
        TraceIdGen::new()
    }
}

impl TraceIdGen {
    pub fn new() -> TraceIdGen {
        TraceIdGen {
            hi: (std::process::id() as u64) << 32,
            seq: AtomicU64::new(1),
        }
    }

    /// Next id; nonzero by construction.
    pub fn next(&self) -> u64 {
        let low = self.seq.fetch_add(1, Ordering::Relaxed) & 0xffff_ffff;
        (self.hi | low).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_path_codes_roundtrip() {
        for p in CachePath::ALL {
            assert_eq!(CachePath::from_code(p as u8), p);
        }
        assert_eq!(CachePath::from_code(200), CachePath::Unknown);
    }

    #[test]
    fn span_builder_produces_monotone_offsets_within_total() {
        let mut b = SpanBuilder::begin(42);
        let t0 = b.t0();
        b.stage(Stage::QueueWait, t0, 100);
        b.stage_at(Stage::ExecutePlan, 150, 300);
        b.cache_path(CachePath::Cold);
        std::thread::sleep(std::time::Duration::from_millis(1));
        let span = b.finish();
        assert_eq!(span.trace_id, 42);
        assert_eq!(span.cache_path, CachePath::Cold as u8);
        assert_eq!(span.outcome, SPAN_OK);
        assert_eq!(span.stages.len(), 2);
        assert!(span.stages[0].start_ns <= span.stages[1].start_ns);
        assert!(span.total_ns >= 1_000_000, "slept ≥ 1ms");
        let render = span.render();
        assert!(render.contains("path cold"), "{render}");
        assert!(render.contains("queue@"), "{render}");
    }

    #[test]
    fn stage_started_before_the_epoch_clamps_to_zero() {
        let early = Instant::now();
        std::thread::sleep(std::time::Duration::from_millis(1));
        let mut b = SpanBuilder::begin(1);
        b.stage(Stage::Admission, early, 10);
        let span = b.finish();
        assert_eq!(span.stages[0].start_ns, 0);
    }

    #[test]
    fn trace_ids_are_unique_and_nonzero() {
        let g = TraceIdGen::new();
        let a = g.next();
        let b = g.next();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }
}
