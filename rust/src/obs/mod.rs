//! Observability: stage-latency histograms, request-lifecycle tracing,
//! and a bounded flight recorder — std-only, shared by every layer of
//! the serving path.
//!
//! The paper's thesis is that *attributable* feedback beats a scalar
//! score; this module applies the same idea to the serving fabric
//! itself.  [`ServiceStats`](crate::coordinator::ServiceStats) says how
//! *much* work happened; `obs` says *where the time went*:
//!
//! * [`hist`] — mergeable log2-bucket latency histograms with atomic
//!   buckets (one relaxed `fetch_add` per sample, no lock on the hot
//!   path), recorded per pipeline [`Stage`]: client send→reply, router
//!   route + upstream, shard queue wait, admission, each cache path
//!   (feedback-hit / decision-hit / splice / cold), decision
//!   resolution, plan execution, and reply write.  Percentile
//!   extraction follows the same nearest-rank rule as
//!   [`crate::util::stats::percentile_sorted`], so histogram p50/p99
//!   agree with exact sample percentiles to within one bucket width.
//! * [`trace`] — client-stamped trace ids ride the wire as trailing
//!   optional fields (the Stats-tail zero-fill rule, so untraced
//!   traffic is byte-identical to older peers) and produce per-request
//!   [`SpanRecord`]s: monotonic stage timestamps relative to the span
//!   start, the cache-path outcome, and the serving wall time.
//!   Tracing is *inert*: ids and spans never influence evaluation,
//!   caching, or scheduling, so traced campaigns are bit-identical to
//!   untraced ones.
//! * [`recorder`] — a bounded ring buffer of recent spans (traced,
//!   errored, shed, rerouted, or slow requests), dumpable over the wire
//!   via `Request::TraceDump` and printed automatically when
//!   `chaos-smoke` / `fleet-smoke` fail, so injected-fault runs leave a
//!   forensic trail instead of just a final score.

pub mod hist;
pub mod recorder;
pub mod trace;

pub use hist::{
    fmt_ns, merge_stage_hists, Hist, HistSnapshot, Stage, StageHistSnapshot,
    StageSet, BUCKETS,
};
pub use recorder::FlightRecorder;
pub use trace::{
    CachePath, EvalTelemetry, SpanBuilder, SpanRecord, StageSpan, TraceIdGen,
    SPAN_ERROR, SPAN_OK, SPAN_REROUTED, SPAN_SHED,
};

use std::sync::atomic::{AtomicU64, Ordering};

/// One process's telemetry hub: the per-stage histogram set, cache-path
/// counters, and the flight recorder.  The [`EvalService`] owns one
/// (shared with the server that fronts it); the router owns its own.
///
/// [`EvalService`]: crate::coordinator::EvalService
pub struct Telemetry {
    pub stages: StageSet,
    pub recorder: FlightRecorder,
    /// Cache-path outcome counters, indexed by [`CachePath`] code.
    paths: [AtomicU64; CachePath::COUNT],
    /// Untraced requests slower than this still land in the recorder
    /// (`MAPPEROPT_TRACE_SLOW_MS`, default 1000; `0` disables).
    pub slow_ns: u64,
}

impl Telemetry {
    /// Telemetry with the recorder ring and slow threshold read from
    /// `MAPPEROPT_TRACE_RING` / `MAPPEROPT_TRACE_SLOW_MS`.
    pub fn from_env() -> Telemetry {
        const ZERO: AtomicU64 = AtomicU64::new(0);
        let slow_ms = std::env::var("MAPPEROPT_TRACE_SLOW_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(1000);
        Telemetry {
            stages: StageSet::new(),
            recorder: FlightRecorder::from_env(),
            paths: [ZERO; CachePath::COUNT],
            slow_ns: slow_ms.saturating_mul(1_000_000),
        }
    }

    /// Count one serving outcome on `path`.
    pub fn note_path(&self, path: CachePath) {
        self.paths[path as usize].fetch_add(1, Ordering::Relaxed);
    }

    /// `(path, count)` for every cache path seen at least once.
    pub fn path_counts(&self) -> Vec<(CachePath, u64)> {
        CachePath::ALL
            .iter()
            .filter_map(|&p| {
                match self.paths[p as usize].load(Ordering::Relaxed) {
                    0 => None,
                    n => Some((p, n)),
                }
            })
            .collect()
    }

    /// Should a finished span with this outcome / wall time be kept?
    /// Traced spans always; otherwise only errored / shed / rerouted /
    /// slow ones (the forensic set).
    pub fn keep_span(&self, trace_id: u64, outcome: u8, total_ns: u64) -> bool {
        trace_id != 0
            || outcome != SPAN_OK
            || (self.slow_ns != 0 && total_ns >= self.slow_ns)
    }
}
