//! The flight recorder: a bounded ring buffer of recent
//! [`SpanRecord`]s.
//!
//! Traced requests always land here; untraced ones only when they end
//! badly (error / shed / rerouted) or slowly — the forensic set.  The
//! ring is bounded (`MAPPEROPT_TRACE_RING`, default 1024 spans; `0`
//! disables recording entirely), drops the *oldest* span under
//! pressure, and counts what it dropped, so a long chaos run keeps the
//! most recent evidence without unbounded memory.
//!
//! Dump paths: the `Request::TraceDump` wire frame (served by shard and
//! router alike; the router concatenates its shards' dumps with its
//! own), and the automatic dump `chaos-smoke` / `fleet-smoke` print on
//! assertion failure.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::trace::SpanRecord;

/// Default ring capacity (spans).
pub const DEFAULT_RING: usize = 1024;

/// Bounded ring of recent spans; see module docs.
pub struct FlightRecorder {
    cap: usize,
    ring: Mutex<VecDeque<SpanRecord>>,
    dropped: AtomicU64,
}

impl FlightRecorder {
    pub fn new(cap: usize) -> FlightRecorder {
        FlightRecorder {
            cap,
            ring: Mutex::new(VecDeque::new()),
            dropped: AtomicU64::new(0),
        }
    }

    /// Capacity from `MAPPEROPT_TRACE_RING` (default [`DEFAULT_RING`]).
    pub fn from_env() -> FlightRecorder {
        let cap = std::env::var("MAPPEROPT_TRACE_RING")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(DEFAULT_RING);
        FlightRecorder::new(cap)
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans evicted to make room (not spans filtered before push).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Append a span, evicting the oldest at capacity.  No-op when the
    /// ring is disabled (`cap == 0`).
    pub fn push(&self, span: SpanRecord) {
        if self.cap == 0 {
            return;
        }
        let mut g = self.ring.lock().unwrap();
        if g.len() >= self.cap {
            g.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        g.push_back(span);
    }

    /// Copy of the ring, oldest first (what `TraceDump` ships).
    pub fn dump(&self) -> Vec<SpanRecord> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    /// Human-readable dump block (the smoke-failure forensic trail).
    pub fn render(spans: &[SpanRecord]) -> String {
        if spans.is_empty() {
            return "flight recorder: no spans recorded\n".to_string();
        }
        let mut out = format!("flight recorder: {} span(s)\n", spans.len());
        for s in spans {
            out.push_str("  ");
            out.push_str(&s.render());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(id: u64) -> SpanRecord {
        SpanRecord { trace_id: id, ..SpanRecord::default() }
    }

    #[test]
    fn ring_is_bounded_and_drops_oldest() {
        let r = FlightRecorder::new(3);
        for i in 1..=5 {
            r.push(span(i));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let ids: Vec<u64> = r.dump().iter().map(|s| s.trace_id).collect();
        assert_eq!(ids, vec![3, 4, 5], "oldest spans evicted first");
    }

    #[test]
    fn zero_capacity_disables_recording() {
        let r = FlightRecorder::new(0);
        r.push(span(1));
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn render_is_one_line_per_span() {
        let r = FlightRecorder::new(8);
        assert!(FlightRecorder::render(&r.dump()).contains("no spans"));
        r.push(span(7));
        r.push(span(8));
        let text = FlightRecorder::render(&r.dump());
        assert!(text.contains("2 span(s)"), "{text}");
        assert_eq!(text.lines().count(), 3, "{text}");
    }
}
