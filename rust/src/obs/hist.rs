//! Mergeable log2-bucket latency histograms with atomic buckets.
//!
//! A sample of `v` nanoseconds lands in bucket `⌊log2 v⌋ + 1` (bucket 0
//! holds exact zeros), so bucket `i ≥ 1` covers `[2^(i-1), 2^i - 1]` and
//! recording is one relaxed `fetch_add` — cheap enough for every
//! request on the serving hot path.  Snapshots are plain `Vec<u64>`
//! bucket counts that merge by element-wise addition (what
//! `StatsSnapshot::aggregate_fleet` does across shards) and extract
//! percentiles with the same nearest-rank rule as
//! [`percentile_sorted`](crate::util::stats::percentile_sorted): the
//! returned value is the containing bucket's upper bound, so it agrees
//! with the exact sample percentile to within one bucket width.

use std::sync::atomic::{AtomicU64, Ordering};

/// Bucket count: `2^(BUCKETS-2) - 1` ns (≈ 1.6 days) saturates the last
/// bucket, far beyond any request latency this fabric serves.
pub const BUCKETS: usize = 48;

/// Bucket index of a nanosecond sample.
#[inline]
fn bucket_of(ns: u64) -> usize {
    if ns == 0 {
        0
    } else {
        ((64 - ns.leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Upper bound (inclusive) of a bucket — what percentile extraction
/// reports for ranks landing in it.
#[inline]
fn bucket_upper(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        (1u64 << i) - 1
    }
}

/// One lock-free latency histogram (counts only; the log2 bucket layout
/// above).  Recording never blocks and tolerates any thread count.
pub struct Hist {
    buckets: [AtomicU64; BUCKETS],
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

impl Hist {
    pub fn new() -> Hist {
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Hist { buckets: [ZERO; BUCKETS] }
    }

    /// Record one sample of `ns` nanoseconds.
    #[inline]
    pub fn record(&self, ns: u64) {
        self.buckets[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
    }

    /// Plain-data copy (trailing zero buckets trimmed, so empty
    /// histograms snapshot to an empty `Vec` and stay off the wire).
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        while buckets.last() == Some(&0) {
            buckets.pop();
        }
        HistSnapshot { buckets }
    }
}

/// Plain-data histogram: bucket counts in the [`Hist`] layout, possibly
/// trimmed of trailing zeros.  This is what rides the `Stats` wire tail
/// and what fleets merge.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct HistSnapshot {
    pub buckets: Vec<u64>,
}

impl HistSnapshot {
    /// Build the histogram of a raw sample set (tests and local
    /// conversions; the serving path records into [`Hist`] directly).
    pub fn of_samples(samples: &[u64]) -> HistSnapshot {
        let mut buckets = vec![0u64; BUCKETS];
        for &s in samples {
            buckets[bucket_of(s)] += 1;
        }
        while buckets.last() == Some(&0) {
            buckets.pop();
        }
        HistSnapshot { buckets }
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.buckets.iter().fold(0u64, |a, &b| a.saturating_add(b))
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Element-wise bucket addition (shorter operand zero-extends).
    pub fn merge(&mut self, other: &HistSnapshot) {
        if other.buckets.len() > self.buckets.len() {
            self.buckets.resize(other.buckets.len(), 0);
        }
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a = a.saturating_add(*b);
        }
    }

    /// Nearest-rank percentile (`p` in 0..=100), reported as the
    /// containing bucket's inclusive upper bound; `0` when empty.  The
    /// rank rule matches `percentile_sorted`, so on the same samples
    /// the two agree to within one bucket width.
    pub fn percentile(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * n as f64).ceil() as u64;
        let rank = rank.clamp(1, n);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen = seen.saturating_add(c);
            if seen >= rank {
                return bucket_upper(i);
            }
        }
        bucket_upper(self.buckets.len().saturating_sub(1))
    }

    /// Upper bound of the highest non-empty bucket (an upper estimate
    /// of the maximum sample); `0` when empty.
    pub fn max(&self) -> u64 {
        self.buckets
            .iter()
            .rposition(|&c| c != 0)
            .map_or(0, bucket_upper)
    }
}

/// A pipeline stage with a recorded latency histogram.  Codes are wire
/// stable: they ride the `Stats` histogram tail and `TraceDump` span
/// records, and unknown codes pass through undecoded (forward
/// compatibility), so variants must never be renumbered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Stage {
    /// Client: submit → reply available (includes retries and the wire).
    ClientSend = 0,
    /// Router: routing decision (ring lookup + dispatch bookkeeping).
    RouterRoute = 1,
    /// Router: backend send → upstream reply.
    RouterUpstream = 2,
    /// Shard: job enqueue → worker pop.
    QueueWait = 3,
    /// Server: request decode → admitted / shed (dispatch overhead).
    Admission = 4,
    /// Serving time of a text-level feedback-cache hit.
    CacheHit = 5,
    /// Serving time of a semantic decision-cache hit.
    CacheDecisionHit = 6,
    /// Serving time of a delta-spliced evaluation.
    CacheSplice = 7,
    /// Serving time of a cold (full simulation) evaluation.
    CacheCold = 8,
    /// `resolve_decisions` alone.
    ResolveDecisions = 9,
    /// Plan execution alone (full, spliced, or legacy engine).
    ExecutePlan = 10,
    /// Server: reply encoded → write buffer drained.
    ReplyWrite = 11,
}

impl Stage {
    pub const COUNT: usize = 12;

    pub const ALL: [Stage; Stage::COUNT] = [
        Stage::ClientSend,
        Stage::RouterRoute,
        Stage::RouterUpstream,
        Stage::QueueWait,
        Stage::Admission,
        Stage::CacheHit,
        Stage::CacheDecisionHit,
        Stage::CacheSplice,
        Stage::CacheCold,
        Stage::ResolveDecisions,
        Stage::ExecutePlan,
        Stage::ReplyWrite,
    ];

    pub fn from_code(code: u8) -> Option<Stage> {
        Stage::ALL.get(code as usize).copied()
    }

    /// Short render name (the `top` / summary tables).
    pub fn name(self) -> &'static str {
        match self {
            Stage::ClientSend => "client",
            Stage::RouterRoute => "route",
            Stage::RouterUpstream => "upstream",
            Stage::QueueWait => "queue",
            Stage::Admission => "admit",
            Stage::CacheHit => "hit",
            Stage::CacheDecisionHit => "decision",
            Stage::CacheSplice => "splice",
            Stage::CacheCold => "cold",
            Stage::ResolveDecisions => "resolve",
            Stage::ExecutePlan => "sim",
            Stage::ReplyWrite => "write",
        }
    }

    /// Render name of a raw (possibly future) stage code.
    pub fn name_of(code: u8) -> String {
        match Stage::from_code(code) {
            Some(s) => s.name().to_string(),
            None => format!("stage{code}"),
        }
    }
}

/// One stage's histogram in a `StatsSnapshot` (and its wire tail).
/// `stage` stays a raw code so snapshots from newer peers with more
/// stages aggregate and render instead of failing to decode.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct StageHistSnapshot {
    pub stage: u8,
    pub hist: HistSnapshot,
}

/// Merge `from` into `to` by stage code (element-wise bucket addition;
/// unseen stages append).  Keeps codes sorted for stable rendering.
pub fn merge_stage_hists(to: &mut Vec<StageHistSnapshot>, from: &[StageHistSnapshot]) {
    for f in from {
        match to.iter_mut().find(|t| t.stage == f.stage) {
            Some(t) => t.hist.merge(&f.hist),
            None => to.push(f.clone()),
        }
    }
    to.sort_by_key(|t| t.stage);
}

/// The full per-stage histogram set of one process.
pub struct StageSet {
    hists: [Hist; Stage::COUNT],
}

impl Default for StageSet {
    fn default() -> StageSet {
        StageSet::new()
    }
}

impl StageSet {
    pub fn new() -> StageSet {
        StageSet { hists: std::array::from_fn(|_| Hist::new()) }
    }

    /// Record one `ns` sample on `stage`.
    #[inline]
    pub fn record(&self, stage: Stage, ns: u64) {
        self.hists[stage as usize].record(ns);
    }

    /// Record the elapsed time of `since` on `stage`, returning the
    /// measured nanoseconds (for reuse in span records).
    #[inline]
    pub fn record_since(&self, stage: Stage, since: std::time::Instant) -> u64 {
        let ns = since.elapsed().as_nanos() as u64;
        self.record(stage, ns);
        ns
    }

    /// Snapshots of every stage that recorded at least one sample, in
    /// stage-code order (empty stages stay off the wire).
    pub fn snapshots(&self) -> Vec<StageHistSnapshot> {
        Stage::ALL
            .iter()
            .filter_map(|&s| {
                let hist = self.hists[s as usize].snapshot();
                (!hist.is_empty())
                    .then(|| StageHistSnapshot { stage: s as u8, hist })
            })
            .collect()
    }
}

/// Human-friendly nanosecond rendering (`978ns`, `12.4µs`, `3.1ms`,
/// `2.50s`) for summaries and the `top` table.
pub fn fmt_ns(ns: u64) -> String {
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.1}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.1}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::percentile_sorted;

    #[test]
    fn buckets_cover_the_u64_range_in_log2_steps() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        // every bucket's upper bound maps back into that bucket
        for i in 1..BUCKETS - 1 {
            assert_eq!(bucket_of(bucket_upper(i)), i, "bucket {i}");
            assert_eq!(bucket_of(bucket_upper(i) + 1), i + 1);
        }
    }

    #[test]
    fn snapshot_trims_trailing_zeros_and_merges_elementwise() {
        let h = Hist::new();
        assert!(h.snapshot().is_empty());
        h.record(0);
        h.record(5);
        h.record(5);
        let s = h.snapshot();
        assert_eq!(s.count(), 3);
        assert_eq!(s.buckets.len(), bucket_of(5) + 1, "trailing zeros trimmed");
        let mut m = HistSnapshot::default();
        m.merge(&s);
        m.merge(&s);
        assert_eq!(m.count(), 6);
        assert_eq!(m.buckets[bucket_of(5)], 4);
    }

    #[test]
    fn percentiles_track_percentile_sorted_within_one_bucket() {
        // deterministic LCG over a latency-like spread (ns .. seconds)
        let mut x = 0x1234_5678_9abc_def0u64;
        let mut samples: Vec<u64> = (0..4096)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (x >> 33) % 2_000_000_000
            })
            .collect();
        let hist = HistSnapshot::of_samples(&samples);
        samples.sort_unstable();
        let sorted: Vec<f64> = samples.iter().map(|&s| s as f64).collect();
        for p in [0.0, 10.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
            let exact = percentile_sorted(&sorted, p) as u64;
            let est = hist.percentile(p);
            assert_eq!(
                bucket_of(exact),
                bucket_of(est),
                "p{p}: exact {exact} and estimate {est} must share a bucket"
            );
            assert!(est >= exact, "upper-bound estimate (p{p}: {est} < {exact})");
            let width = 1u64 << (bucket_of(exact).saturating_sub(1));
            assert!(est - exact < width, "p{p}: off by ≥ one bucket width");
        }
    }

    #[test]
    fn merged_histograms_equal_the_histogram_of_concatenated_samples() {
        let a: Vec<u64> = (0..500).map(|i| i * 37).collect();
        let b: Vec<u64> = (0..300).map(|i| i * 911 + 5).collect();
        let mut merged = HistSnapshot::of_samples(&a);
        merged.merge(&HistSnapshot::of_samples(&b));
        let mut all = a.clone();
        all.extend_from_slice(&b);
        assert_eq!(merged, HistSnapshot::of_samples(&all));
    }

    #[test]
    fn stage_set_snapshots_only_recorded_stages() {
        let s = StageSet::new();
        assert!(s.snapshots().is_empty());
        s.record(Stage::QueueWait, 100);
        s.record(Stage::ExecutePlan, 1_000_000);
        let snaps = s.snapshots();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[0].stage, Stage::QueueWait as u8);
        assert_eq!(snaps[1].stage, Stage::ExecutePlan as u8);
        assert_eq!(snaps[1].hist.count(), 1);
    }

    #[test]
    fn merge_stage_hists_adds_by_code_and_sorts() {
        let mut to = vec![StageHistSnapshot {
            stage: 8,
            hist: HistSnapshot::of_samples(&[10]),
        }];
        let from = vec![
            StageHistSnapshot { stage: 3, hist: HistSnapshot::of_samples(&[7]) },
            StageHistSnapshot { stage: 8, hist: HistSnapshot::of_samples(&[9]) },
        ];
        merge_stage_hists(&mut to, &from);
        assert_eq!(to.len(), 2);
        assert_eq!(to[0].stage, 3);
        assert_eq!(to[1].stage, 8);
        assert_eq!(to[1].hist.count(), 2);
    }

    #[test]
    fn stage_codes_roundtrip_and_name() {
        for s in Stage::ALL {
            assert_eq!(Stage::from_code(s as u8), Some(s));
        }
        assert_eq!(Stage::from_code(Stage::COUNT as u8), None);
        assert_eq!(Stage::name_of(3), "queue");
        assert_eq!(Stage::name_of(200), "stage200");
    }

    #[test]
    fn fmt_ns_picks_sane_units() {
        assert_eq!(fmt_ns(999), "999ns");
        assert_eq!(fmt_ns(1_500), "1.5µs");
        assert_eq!(fmt_ns(2_500_000), "2.5ms");
        assert_eq!(fmt_ns(1_500_000_000), "1.50s");
    }
}
