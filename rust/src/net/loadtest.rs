//! `mapperopt loadtest` — a synthetic-client load generator for the
//! multiplexed [`EvalServer`](super::EvalServer).
//!
//! The harness answers one question: how many concurrent campaign
//! clients can one server process sustain, and at what latency?  It
//! spins up thousands of *synthetic* clients — each one a real TCP
//! connection speaking the real wire protocol, but multiplexed in
//! batches onto a few generator threads with the same
//! nonblocking-socket technique the server itself uses, so the
//! generator can drive far more connections than it has threads (the
//! old thread-per-connection client model could never have generated
//! this load from one process).
//!
//! Two driving modes:
//!
//! * **closed loop** (default): every client keeps `pipeline` requests
//!   in flight and sends the next the moment one completes — measures
//!   sustainable throughput under full back-to-back load;
//! * **open loop** (`--rate R`): clients submit at a fixed aggregate
//!   rate regardless of completions — measures latency at a controlled
//!   arrival rate, the number an SLO conversation actually needs
//!   (closed-loop latency self-throttles and flatters the server).
//!
//! Clients cycle a small set of `--distinct` mapper variants, so after
//! one warmup evaluation per variant the server answers from its
//! feedback cache and the measurement stresses the *serving* path —
//! framing, admission, multiplexing — not the simulator.  `--batch K`
//! coalesces each client's submissions into `EvalBatch` frames of K
//! items, exercising the batch wire path under load.
//!
//! The report carries client-observed throughput and p50/p99/p999
//! latency plus the server's own [`StatsSnapshot`] (shed / refused /
//! reaped counters), and serializes to one JSON object for
//! `BENCH_serve.json`.
//!
//! `--router` switches to the **fleet sweep** ([`run_fleet`]): for each
//! shard count it boots that many in-process `EvalServer` shards plus
//! an [`EvalRouter`](super::EvalRouter) front, drives the same client
//! load through the router, and reports per-point throughput, tail
//! latency, and fleet-aggregate cache hit rate (plus per-shard routed
//! counts from the stats tail) — the near-linear-scaling evidence of
//! `BENCH_fleet.json`.  A `shards = 1, via_router = false` baseline
//! point drives one bare server with the identical load so the scaling
//! ratio has a denominator.

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::{EvalService, StatsSnapshot, PRIORITY_NORMAL};
use crate::obs::{fmt_ns, SpanRecord, Stage};
use crate::sim::ExecMode;
use crate::util::stats::percentile_sorted;

use super::client::RemoteEvalClient;
use super::proto::{
    self, BatchItem, ErrorKind, FrameStep, Request, Response, Scenario, SpecRef,
    WireEvalRequest,
};
use super::router::EvalRouter;
use super::server::{EvalServer, ServerConfig};

/// Knobs of one loadtest run (see module docs; defaults match
/// `mapperopt loadtest` with no flags).
#[derive(Debug, Clone)]
pub struct LoadtestConfig {
    /// Concurrent synthetic clients (one TCP connection each).
    pub clients: usize,
    /// Measurement window (excludes the per-variant warmup).
    pub duration: Duration,
    /// `Some(r)`: open loop at `r` aggregate requests/s; `None`: closed
    /// loop.
    pub rate: Option<f64>,
    /// Closed-loop in-flight frames per client.
    pub pipeline: usize,
    /// Items per `EvalBatch` frame (`<= 1` sends single `Eval` frames).
    pub batch: usize,
    /// Distinct mapper variants cycled (distinct cache entries).
    pub distinct: usize,
    /// Generator threads (`0` = `min(8, cores)`).
    pub generators: usize,
}

impl Default for LoadtestConfig {
    fn default() -> LoadtestConfig {
        LoadtestConfig {
            clients: 1000,
            duration: Duration::from_secs(10),
            rate: None,
            pipeline: 1,
            batch: 1,
            distinct: 8,
            generators: 0,
        }
    }
}

/// What one run measured, across all generator threads.
#[derive(Debug, Clone, Default)]
pub struct LoadtestReport {
    pub clients: usize,
    /// Clients whose dial + first response round-trip succeeded.
    pub connected: usize,
    /// Dials that never established (connect error / EMFILE).
    pub dial_failures: u64,
    /// Evaluations answered with feedback.
    pub completed: u64,
    /// Items answered `Overloaded` (queue or in-flight shedding).
    pub shed: u64,
    /// Connections refused at the server's connection capacity.
    pub refused: u64,
    /// Items answered with any other classified error.
    pub errors: u64,
    /// Connections that died mid-run (EOF, reset, reap).
    pub conn_deaths: u64,
    /// Measurement window actually elapsed, seconds.
    pub elapsed_s: f64,
    /// Completed evaluations per second over the window.
    pub throughput: f64,
    /// Client-observed frame latencies, milliseconds.
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    /// The server's own counters, fetched after the run.
    pub server: Option<StatsSnapshot>,
}

/// `stage n p50 p99` fragments of a snapshot's histogram tail (the
/// server-side answer to "where did the time go" next to the
/// client-observed percentiles above it).
fn stage_text(snap: &StatsSnapshot) -> String {
    snap.stage_hists
        .iter()
        .map(|h| {
            format!(
                "{} n={} p50 {} p99 {}",
                Stage::name_of(h.stage),
                h.hist.count(),
                fmt_ns(h.hist.percentile(50.0)),
                fmt_ns(h.hist.percentile(99.0)),
            )
        })
        .collect::<Vec<_>>()
        .join(" | ")
}

/// The same histogram tail as JSON array elements.
fn stage_json(snap: &StatsSnapshot) -> String {
    snap.stage_hists
        .iter()
        .map(|h| {
            format!(
                "{{\"stage\":\"{}\",\"count\":{},\"p50_ns\":{},\"p99_ns\":{}}}",
                Stage::name_of(h.stage),
                h.hist.count(),
                h.hist.percentile(50.0),
                h.hist.percentile(99.0),
            )
        })
        .collect::<Vec<_>>()
        .join(",")
}

impl LoadtestReport {
    /// Human-readable multi-line summary.
    pub fn text(&self) -> String {
        let mut s = format!(
            "loadtest: {}/{} clients connected ({} dial failures)\n\
             {:.1} evals/s over {:.1}s — {} completed, {} shed, {} refused \
             dials, {} errors, {} connection deaths\n\
             latency p50 {:.2} ms  p99 {:.2} ms  p99.9 {:.2} ms\n",
            self.connected,
            self.clients,
            self.dial_failures,
            self.throughput,
            self.elapsed_s,
            self.completed,
            self.shed,
            self.refused,
            self.errors,
            self.conn_deaths,
            self.p50_ms,
            self.p99_ms,
            self.p999_ms,
        );
        if let Some(sv) = &self.server {
            s.push_str(&format!(
                "server: {} evals, {} cache hits, {} shed, {} refused \
                 connections, {} reaped connections\n",
                sv.evals,
                sv.cache_hits,
                sv.shed_requests,
                sv.refused_connections,
                sv.reaped_connections,
            ));
            if !sv.stage_hists.is_empty() {
                s.push_str(&format!("server stages: {}\n", stage_text(sv)));
            }
        }
        s
    }

    /// One JSON object (the `BENCH_serve.json` line).
    pub fn json(&self) -> String {
        let (sv_shed, sv_refused, sv_reaped, sv_evals, sv_hits) = self
            .server
            .as_ref()
            .map(|s| {
                (
                    s.shed_requests,
                    s.refused_connections,
                    s.reaped_connections,
                    s.evals,
                    s.cache_hits,
                )
            })
            .unwrap_or_default();
        let stages =
            self.server.as_ref().map(stage_json).unwrap_or_default();
        format!(
            "{{\"bench\":\"serve_loadtest\",\"clients\":{},\"connected\":{},\
             \"dial_failures\":{},\"completed\":{},\"shed\":{},\"refused\":{},\
             \"errors\":{},\"conn_deaths\":{},\"elapsed_s\":{:.3},\
             \"throughput\":{:.1},\"p50_ms\":{:.3},\"p99_ms\":{:.3},\
             \"p999_ms\":{:.3},\"server_evals\":{},\"server_cache_hits\":{},\
             \"server_shed\":{},\"server_refused_connections\":{},\
             \"server_reaped_connections\":{},\"server_stages\":[{}]}}",
            self.clients,
            self.connected,
            self.dial_failures,
            self.completed,
            self.shed,
            self.refused,
            self.errors,
            self.conn_deaths,
            self.elapsed_s,
            self.throughput,
            self.p50_ms,
            self.p99_ms,
            self.p999_ms,
            sv_evals,
            sv_hits,
            sv_shed,
            sv_refused,
            sv_reaped,
            stages,
        )
    }
}

/// The `--distinct` mapper variants: tiny circuit scenarios differing
/// only in piece count, so each is its own cache entry but every
/// evaluation is milliseconds even cold.
fn variants(distinct: usize) -> Vec<WireEvalRequest> {
    let dsl = crate::mapping::expert_dsl("circuit").expect("circuit expert mapper");
    (0..distinct.max(1))
        .map(|i| WireEvalRequest {
            spec: SpecRef::Name("p100_cluster".into()),
            scenario: Scenario {
                app: "circuit".into(),
                params: vec![
                    ("pieces".into(), 2 + i as i64),
                    ("wires".into(), 256),
                    ("private_nodes".into(), 128),
                    ("shared_nodes".into(), 32),
                    ("steps".into(), 2),
                ],
            },
            dsl: dsl.to_string(),
            mode: ExecMode::Serialized,
            priority: PRIORITY_NORMAL,
            trace_id: 0,
        })
        .collect()
}

/// Pre-encode the wire frames the clients replay: one frame per
/// variant (single mode) or per variant-aligned chunk (batch mode).
/// Returns `(frame bytes, evals per frame)` pairs.
fn encode_frames(cfg: &LoadtestConfig) -> Vec<(Vec<u8>, u32)> {
    let vars = variants(cfg.distinct);
    let batch = cfg.batch.clamp(1, proto::MAX_BATCH_ITEMS);
    let mut frames = Vec::new();
    if batch <= 1 {
        for v in &vars {
            let mut buf = Vec::new();
            proto::write_frame(&mut buf, &Request::Eval(v.clone()).encode())
                .expect("loadtest frames are tiny");
            frames.push((buf, 1));
        }
    } else {
        // chunk the variant cycle so every batch still spreads over the
        // distinct set (rotating the start keeps chunks unequal)
        for start in 0..vars.len() {
            let items: Vec<WireEvalRequest> = (0..batch)
                .map(|j| vars[(start + j) % vars.len()].clone())
                .collect();
            let mut buf = Vec::new();
            proto::write_frame(&mut buf, &Request::EvalBatch(items).encode())
                .expect("loadtest frames are tiny");
            frames.push((buf, batch as u32));
        }
    }
    frames
}

/// One synthetic client: a nonblocking connection replaying pre-encoded
/// frames and matching responses FIFO.
struct SynthClient {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    wpos: usize,
    /// Send instant and eval count of each in-flight frame.
    pending: VecDeque<(Instant, u32)>,
    /// Cursor into the pre-encoded frame cycle.
    frame_idx: usize,
    /// Open-loop: next permitted send instant.
    next_send: Instant,
    /// Whether any response ever arrived (drives `connected`).
    answered: bool,
    dead: bool,
    refused: bool,
}

/// Counters one generator thread accumulates (merged at the end).
#[derive(Default)]
struct GenTally {
    connected: u64,
    dial_failures: u64,
    completed: u64,
    shed: u64,
    refused: u64,
    errors: u64,
    conn_deaths: u64,
    latencies_ms: Vec<f64>,
}

/// Drive `n_clients` synthetic clients until `stop_at`, then drain
/// briefly and report.
#[allow(clippy::too_many_arguments)]
fn generator(
    addr: SocketAddr,
    n_clients: usize,
    frames: Vec<(Vec<u8>, u32)>,
    pipeline: usize,
    send_interval: Option<Duration>,
    stop_at: Instant,
    offset: usize,
) -> GenTally {
    let mut tally = GenTally::default();
    let mut conns: Vec<SynthClient> = Vec::with_capacity(n_clients);
    for i in 0..n_clients {
        // a brief retry absorbs accept-backlog overflow during the
        // thundering-herd ramp; a persistent failure is counted
        let mut dialed = None;
        for attempt in 0..3 {
            match TcpStream::connect(addr) {
                Ok(s) => {
                    dialed = Some(s);
                    break;
                }
                Err(_) if attempt + 1 < 3 => {
                    thread::sleep(Duration::from_millis(10 << attempt));
                }
                Err(_) => {}
            }
        }
        let Some(stream) = dialed else {
            tally.dial_failures += 1;
            continue;
        };
        let _ = stream.set_nodelay(true);
        if stream.set_nonblocking(true).is_err() {
            tally.dial_failures += 1;
            continue;
        }
        let now = Instant::now();
        conns.push(SynthClient {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            pending: VecDeque::new(),
            // stagger cursors so clients do not lock-step on one cache
            // entry, and stagger open-loop phases across the window
            frame_idx: (offset + i) % frames.len(),
            next_send: now
                + send_interval
                    .map(|iv| iv.mul_f64(i as f64 / n_clients.max(1) as f64))
                    .unwrap_or(Duration::ZERO),
            answered: false,
            dead: false,
            refused: false,
        });
    }

    let mut idle_spins: u32 = 0;
    loop {
        let now = Instant::now();
        let sending = now < stop_at;
        let mut progressed = false;
        let mut all_quiet = true;
        for c in conns.iter_mut() {
            if c.dead {
                continue;
            }
            // enqueue new frames per the driving mode
            if sending {
                let want = match send_interval {
                    // open loop: one frame per elapsed interval
                    Some(iv) => {
                        if now >= c.next_send {
                            c.next_send += iv;
                            1
                        } else {
                            0
                        }
                    }
                    // closed loop: top the pipeline back up
                    None => pipeline.saturating_sub(c.pending.len()),
                };
                for _ in 0..want {
                    let (bytes, items) = &frames[c.frame_idx % frames.len()];
                    c.frame_idx += 1;
                    c.wbuf.extend_from_slice(bytes);
                    c.pending.push_back((Instant::now(), *items));
                    progressed = true;
                }
            }
            // flush
            while c.wpos < c.wbuf.len() {
                match c.stream.write(&c.wbuf[c.wpos..]) {
                    Ok(0) => {
                        c.dead = true;
                        break;
                    }
                    Ok(n) => {
                        c.wpos += n;
                        progressed = true;
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        c.dead = true;
                        break;
                    }
                }
            }
            if c.wpos == c.wbuf.len() {
                c.wbuf.clear();
                c.wpos = 0;
            }
            // read + match responses
            let mut tmp = [0u8; 16 << 10];
            while !c.dead {
                match c.stream.read(&mut tmp) {
                    Ok(0) => {
                        c.dead = true;
                    }
                    Ok(n) => {
                        c.rbuf.extend_from_slice(&tmp[..n]);
                        progressed = true;
                        continue;
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {}
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        c.dead = true;
                    }
                }
                break;
            }
            loop {
                match proto::frame_step(&c.rbuf) {
                    FrameStep::Incomplete => break,
                    FrameStep::Corrupt(_) => {
                        c.dead = true;
                        break;
                    }
                    FrameStep::Frame { payload, consumed } => {
                        c.rbuf.drain(..consumed);
                        progressed = true;
                        settle(c, &payload, &mut tally);
                    }
                }
            }
            if c.dead {
                if c.refused {
                    tally.refused += 1;
                } else {
                    tally.conn_deaths += 1;
                }
            }
            if !c.pending.is_empty() {
                all_quiet = false;
            }
        }
        if !sending && all_quiet {
            break;
        }
        if !sending && now > stop_at + Duration::from_secs(2) {
            break; // drain grace expired; leftover pendings are lost
        }
        if progressed {
            idle_spins = 0;
        } else {
            idle_spins = idle_spins.saturating_add(1);
            if idle_spins <= 3 {
                thread::yield_now();
            } else {
                thread::sleep(Duration::from_micros(
                    (50 * idle_spins as u64).min(500),
                ));
            }
        }
    }
    tally.connected = conns.iter().filter(|c| c.answered).count() as u64;
    tally
}

/// Classify one response frame against the client's pending FIFO.
fn settle(c: &mut SynthClient, payload: &[u8], tally: &mut GenTally) {
    let resp = match Response::decode(payload) {
        Ok(r) => r,
        Err(_) => {
            c.dead = true;
            return;
        }
    };
    let Some((sent_at, items)) = c.pending.pop_front() else {
        // a response with nothing in flight: the server refused the
        // dial at its connection cap (sent before reading anything) or
        // reaped us idle — either way this connection is over
        if let Response::Error { kind, msg, .. } = &resp {
            if *kind == ErrorKind::Overloaded && msg.contains("connection capacity")
            {
                c.refused = true;
            }
        }
        c.dead = true;
        return;
    };
    c.answered = true;
    let ms = sent_at.elapsed().as_secs_f64() * 1e3;
    tally.latencies_ms.push(ms);
    match resp {
        Response::Feedback(_) => tally.completed += 1,
        Response::FeedbackBatch(batch) => {
            for item in batch {
                match item {
                    BatchItem::Feedback(_) => tally.completed += 1,
                    BatchItem::Error { kind: ErrorKind::Overloaded, .. } => {
                        tally.shed += 1
                    }
                    BatchItem::Error { .. } => tally.errors += 1,
                }
            }
        }
        Response::Error { kind: ErrorKind::Overloaded, .. } => {
            tally.shed += u64::from(items);
        }
        Response::Error { .. } => tally.errors += u64::from(items),
        _ => tally.errors += u64::from(items),
    }
}

/// Run the loadtest against a bound server address.  The caller owns
/// the server (in-process or remote); this only generates load and
/// fetches a final [`StatsSnapshot`] through a regular client.
pub fn run(addr: SocketAddr, cfg: &LoadtestConfig) -> LoadtestReport {
    let frames = encode_frames(cfg);

    // warm the per-variant cache entries through a regular client, so
    // the measured window exercises serving, not first-touch simulation
    let warm = RemoteEvalClient::connect(addr).ok();
    if let Some(client) = &warm {
        for v in variants(cfg.distinct) {
            let _ = client.evaluate(v.spec, v.scenario, &v.dsl, v.mode, v.priority);
        }
    }

    let gens = if cfg.generators > 0 {
        cfg.generators
    } else {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8)
    }
    .min(cfg.clients.max(1));
    let per_client_interval = cfg.rate.map(|r| {
        Duration::from_secs_f64(cfg.clients.max(1) as f64 / r.max(0.001))
    });
    let started = Instant::now();
    let stop_at = started + cfg.duration;

    let mut handles = Vec::with_capacity(gens);
    for g in 0..gens {
        // spread the client count as evenly as integer division allows
        let n = cfg.clients / gens + usize::from(g < cfg.clients % gens);
        let frames = frames.clone();
        let pipeline = cfg.pipeline.max(1);
        handles.push(
            thread::Builder::new()
                .name(format!("loadgen-{g}"))
                .spawn(move || {
                    generator(
                        addr,
                        n,
                        frames,
                        pipeline,
                        per_client_interval,
                        stop_at,
                        g * 7919, // co-prime stagger across generators
                    )
                })
                .expect("spawn load generator"),
        );
    }
    let mut tally = GenTally::default();
    for h in handles {
        let t = h.join().expect("load generator panicked");
        tally.connected += t.connected;
        tally.dial_failures += t.dial_failures;
        tally.completed += t.completed;
        tally.shed += t.shed;
        tally.refused += t.refused;
        tally.errors += t.errors;
        tally.conn_deaths += t.conn_deaths;
        tally.latencies_ms.extend(t.latencies_ms);
    }
    let elapsed = started.elapsed().as_secs_f64();

    tally.latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let server = warm.and_then(|c| c.stats().ok());
    LoadtestReport {
        clients: cfg.clients,
        connected: tally.connected as usize,
        dial_failures: tally.dial_failures,
        completed: tally.completed,
        shed: tally.shed,
        refused: tally.refused,
        errors: tally.errors,
        conn_deaths: tally.conn_deaths,
        elapsed_s: elapsed,
        throughput: tally.completed as f64 / elapsed.max(1e-9),
        p50_ms: percentile_sorted(&tally.latencies_ms, 50.0),
        p99_ms: percentile_sorted(&tally.latencies_ms, 99.0),
        p999_ms: percentile_sorted(&tally.latencies_ms, 99.9),
        server,
    }
}

/// One point of the fleet sweep: the same client load driven at a
/// baseline bare server (`via_router = false`) or at an
/// [`EvalRouter`] fronting `shards` in-process shards.
#[derive(Debug, Clone)]
pub struct FleetPoint {
    pub shards: usize,
    pub via_router: bool,
    /// In-flight requests the router failed over off dead shards
    /// (zero in a healthy sweep).
    pub rerouted: u64,
    pub report: LoadtestReport,
    /// Rendered flight-recorder spans fetched from the front when the
    /// point finished unhealthy (empty otherwise) — the forensic trail
    /// a failed `fleet-smoke` prints.
    pub forensics: Vec<String>,
}

/// Whether one measured point actually served its load (the per-point
/// half of [`FleetReport::healthy`]; an unhealthy point gets its
/// flight recorder pulled before the fleet is torn down).
fn point_healthy(r: &LoadtestReport) -> bool {
    r.completed > 0
        && r.errors == 0
        && r.connected >= r.clients - r.clients / 10
}

/// Pull and render the front's flight-recorder spans (best effort: an
/// unreachable front just yields no forensics).
fn fetch_forensics(addr: SocketAddr) -> Vec<String> {
    RemoteEvalClient::connect(addr)
        .ok()
        .and_then(|c| c.trace_dump().ok())
        .map(|spans| spans.iter().map(SpanRecord::render).collect())
        .unwrap_or_default()
}

impl FleetPoint {
    fn label(&self) -> String {
        if self.via_router {
            format!("router x{}", self.shards)
        } else {
            "single server (no router)".to_string()
        }
    }

    /// Fleet-aggregate cache hit rate (router points aggregate the
    /// shard snapshots; the baseline is the server's own).
    pub fn cache_hit_rate(&self) -> f64 {
        self.report.server.as_ref().map(StatsSnapshot::cache_hit_rate).unwrap_or(0.0)
    }

    fn json(&self) -> String {
        let mut per_shard = String::new();
        if let Some(sv) = &self.report.server {
            for (i, sh) in sv.shards.iter().enumerate() {
                if i > 0 {
                    per_shard.push(',');
                }
                per_shard.push_str(&format!(
                    "{{\"addr\":\"{}\",\"state\":{},\"routed\":{},\
                     \"evals\":{},\"cache_hits\":{},\"hit_rate\":{:.4}}}",
                    sh.addr,
                    sh.state,
                    sh.routed,
                    sh.evals,
                    sh.cache_hits,
                    sh.cache_hit_rate(),
                ));
            }
        }
        let (evals, hits) = self
            .report
            .server
            .as_ref()
            .map(|s| (s.evals, s.cache_hits))
            .unwrap_or_default();
        let stages = self
            .report
            .server
            .as_ref()
            .map(stage_json)
            .unwrap_or_default();
        format!(
            "{{\"shards\":{},\"via_router\":{},\"clients\":{},\
             \"completed\":{},\"shed\":{},\"errors\":{},\"rerouted\":{},\
             \"elapsed_s\":{:.3},\"throughput\":{:.1},\"p50_ms\":{:.3},\
             \"p99_ms\":{:.3},\"p999_ms\":{:.3},\"fleet_evals\":{},\
             \"fleet_cache_hits\":{},\"fleet_cache_hit_rate\":{:.4},\
             \"stages\":[{stages}],\"per_shard\":[{}]}}",
            self.shards,
            self.via_router,
            self.report.clients,
            self.report.completed,
            self.report.shed,
            self.report.errors,
            self.rerouted,
            self.report.elapsed_s,
            self.report.throughput,
            self.report.p50_ms,
            self.report.p99_ms,
            self.report.p999_ms,
            evals,
            hits,
            self.cache_hit_rate(),
            per_shard,
        )
    }
}

/// The whole sweep (the `BENCH_fleet.json` object).
#[derive(Debug, Clone, Default)]
pub struct FleetReport {
    pub points: Vec<FleetPoint>,
}

impl FleetReport {
    /// Human-readable sweep table with per-shard routing balance.
    pub fn text(&self) -> String {
        let base = self
            .points
            .iter()
            .find(|p| !p.via_router)
            .map(|p| p.report.throughput)
            .unwrap_or(0.0);
        let mut s = String::from(
            "fleet sweep (same client load per point):\n",
        );
        for p in &self.points {
            let scale = if base > 0.0 {
                format!(" ({:.2}x baseline)", p.report.throughput / base)
            } else {
                String::new()
            };
            s.push_str(&format!(
                "  {:26} {:>9.1} evals/s{}  p50 {:.2} ms  p99 {:.2} ms  \
                 p99.9 {:.2} ms  hit rate {:.1}%  rerouted {}\n",
                p.label(),
                p.report.throughput,
                scale,
                p.report.p50_ms,
                p.report.p99_ms,
                p.report.p999_ms,
                100.0 * p.cache_hit_rate(),
                p.rerouted,
            ));
            if let Some(sv) = &p.report.server {
                for sh in &sv.shards {
                    s.push_str(&format!(
                        "      shard {:21} routed {:>7}  evals {:>7}  \
                         hit rate {:.1}%\n",
                        sh.addr,
                        sh.routed,
                        sh.evals,
                        100.0 * sh.cache_hit_rate(),
                    ));
                }
                if !sv.stage_hists.is_empty() {
                    s.push_str(&format!(
                        "      stages: {}\n",
                        stage_text(sv)
                    ));
                }
            }
            if !p.forensics.is_empty() {
                s.push_str("      flight recorder:\n");
                for line in &p.forensics {
                    s.push_str(&format!("        {line}\n"));
                }
            }
        }
        s
    }

    /// One JSON object (the `BENCH_fleet.json` line).
    pub fn json(&self) -> String {
        let points: Vec<String> =
            self.points.iter().map(FleetPoint::json).collect();
        format!(
            "{{\"bench\":\"fleet_loadtest\",\"points\":[{}]}}",
            points.join(",")
        )
    }

    /// CI gate: every point actually served its load (no hard errors,
    /// nearly all clients connected, something completed).
    pub fn healthy(&self) -> bool {
        !self.points.is_empty()
            && self.points.iter().all(|p| point_healthy(&p.report))
    }
}

/// Boot one in-process shard sized for the sweep's client count.
fn boot_shard(
    workers: usize,
    max_connections: usize,
) -> io::Result<EvalServer> {
    let service = Arc::new(if workers > 0 {
        EvalService::new(workers, 8 * workers)
    } else {
        EvalService::with_defaults()
    });
    EvalServer::bind_with(
        "127.0.0.1:0",
        service,
        ServerConfig { max_connections, ..ServerConfig::default() },
    )
}

/// The fleet sweep: a bare-server baseline point, then one router
/// point per entry of `shard_counts` — identical client load each
/// time, fresh shards each point (no cross-point cache warmth).
/// `workers` sizes each shard's eval pool (`0` = host default).
pub fn run_fleet(
    shard_counts: &[usize],
    cfg: &LoadtestConfig,
    workers: usize,
) -> io::Result<FleetReport> {
    let conn_cap = cfg.clients + 64;
    let mut points = Vec::new();

    // the denominator: one bare server, no router hop
    {
        let server = boot_shard(workers, conn_cap)?;
        let report = run(server.addr(), cfg);
        let forensics = if point_healthy(&report) {
            Vec::new()
        } else {
            fetch_forensics(server.addr())
        };
        server.shutdown();
        points.push(FleetPoint {
            shards: 1,
            via_router: false,
            rerouted: 0,
            report,
            forensics,
        });
    }

    for &n in shard_counts {
        let n = n.max(1);
        let mut shards = Vec::with_capacity(n);
        for _ in 0..n {
            // each router backend lane funnels through the shard's
            // regular connection admission, so the shard cap only
            // needs the router's own connections plus slack
            shards.push(boot_shard(workers, conn_cap)?);
        }
        let addrs: Vec<String> =
            shards.iter().map(|s| s.addr().to_string()).collect();
        let router = EvalRouter::bind_with(
            "127.0.0.1:0",
            &addrs,
            ServerConfig { max_connections: conn_cap, ..ServerConfig::default() },
        )?;
        let report = run(router.addr(), cfg);
        let rerouted = router.rerouted();
        let forensics = if point_healthy(&report) {
            Vec::new()
        } else {
            // the router front answers TraceDump with every shard's
            // spans plus its own — pull while the fleet is still up
            fetch_forensics(router.addr())
        };
        router.shutdown();
        for s in shards {
            s.shutdown();
        }
        points.push(FleetPoint {
            shards: n,
            via_router: true,
            rerouted,
            report,
            forensics,
        });
    }
    Ok(FleetReport { points })
}
