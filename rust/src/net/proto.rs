//! The wire codec: a versioned, length-prefixed binary protocol for the
//! full [`EvalService`](crate::coordinator::EvalService) request /
//! response surface (see the [module docs](super) for the frame
//! layout).
//!
//! Hand-rolled like [`crate::util::hash`]: little-endian fixed-width
//! integers, `u32`-length-prefixed UTF-8 strings, bit-cast `f64`s (so
//! scores survive the wire *bit-identically*), and one tag byte per
//! enum.  Every decoder is total — malformed bytes yield a classified
//! [`DecodeError`], never a panic — and every encoder destructures its
//! struct exhaustively, so adding a field without updating the codec is
//! a compile error, not a silent wire skew.

use std::fmt;
use std::io::{self, Read, Write};

use crate::coordinator::{
    PrioritySnapshot, ShardSnapshot, SpecSnapshot, StatsSnapshot,
};
use crate::feedback::SystemFeedback;
use crate::machine::MachineSpec;
use crate::obs::{
    EvalTelemetry, HistSnapshot, SpanRecord, StageHistSnapshot, StageSpan, BUCKETS,
};
use crate::sim::{CritEntry, ExecMode, PerfProfile};

/// Protocol revision; bumped on any layout change.  Leads every payload
/// so mismatched peers fail with a classified version error.  (v2 added
/// the per-frame checksum trailer — see [`write_frame`].)
pub const WIRE_VERSION: u8 = 2;

/// Upper bound on one frame's payload (DSL mappers, profiles, and stats
/// snapshots are all well under this; anything larger is a framing
/// error, not a legitimate message).  [`read_frame`] enforces this
/// *before* allocating, and grows the body buffer incrementally as
/// bytes actually arrive, so a hostile length prefix can never OOM or
/// abort the process.
pub const MAX_FRAME_LEN: usize = 8 << 20;

/// Upper bound on the item count of one [`Request::EvalBatch`] /
/// [`Response::FeedbackBatch`] frame.  Checked *before* any
/// per-item allocation, so a hostile count prefix claiming millions of
/// entries fails as a classified decode error instead of reserving
/// memory; [`MAX_FRAME_LEN`] independently bounds the total bytes.
pub const MAX_BATCH_ITEMS: usize = 1024;

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

/// Why a payload failed to decode.  Total and panic-free by
/// construction; servers answer these as classified error responses.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// The payload ended before its fields did.
    Truncated,
    /// The payload has bytes left after its last field.
    Trailing(usize),
    /// A string field is not valid UTF-8.
    Utf8,
    /// The payload speaks a protocol version this build does not.
    Version(u8),
    /// Unknown tag byte while decoding `what`.
    UnknownTag(&'static str, u8),
    /// Structurally well-formed but semantically impossible field.
    Invalid(&'static str),
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DecodeError::Truncated => write!(f, "truncated payload"),
            DecodeError::Trailing(n) => write!(f, "{n} trailing bytes after payload"),
            DecodeError::Utf8 => write!(f, "string field is not UTF-8"),
            DecodeError::Version(got) => write!(
                f,
                "unsupported wire version {got} (this build speaks {WIRE_VERSION})"
            ),
            DecodeError::UnknownTag(what, tag) => {
                write!(f, "unknown {what} tag {tag:#04x}")
            }
            DecodeError::Invalid(what) => write!(f, "invalid {what} field"),
        }
    }
}

impl std::error::Error for DecodeError {}

impl DecodeError {
    /// The classified error category a server reports for this failure.
    pub fn wire_kind(&self) -> ErrorKind {
        match self {
            DecodeError::Version(_) => ErrorKind::Version,
            _ => ErrorKind::Decode,
        }
    }
}

/// Classified error categories of [`Response::Error`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// Unrecoverable framing (length prefix outside `1..=MAX_FRAME_LEN`
    /// or a checksum mismatch); the server answers once and closes the
    /// connection.
    Frame,
    /// Version-skewed frame; the connection keeps serving.
    Version,
    /// Undecodable payload; the connection keeps serving.
    Decode,
    /// Well-formed request naming something the server does not have
    /// (unknown spec, unknown app, bad scenario parameter).
    BadRequest,
    /// Server-side failure outside the evaluation path.
    Internal,
    /// The server shed this request under load (queue high-water mark
    /// or per-connection in-flight cap).  Retryable; carries a
    /// retry-after hint in `Response::Error::retry_after_ms`.
    Overloaded,
    /// The server reaped this connection at its idle deadline (no
    /// request activity for `MAPPEROPT_CONN_DEADLINE_S`).  The
    /// connection itself is gone, but the *campaign* is healthy — a
    /// slow-thinking optimizer between proposals is normal — so this is
    /// retryable: the client reconnects and resumes.  Rides at the code
    /// tail so pre-deadline decoders classify it as a plain decode
    /// failure (also retryable) instead of panicking.
    Deadline,
}

impl ErrorKind {
    fn code(self) -> u8 {
        match self {
            ErrorKind::Frame => 0,
            ErrorKind::Version => 1,
            ErrorKind::Decode => 2,
            ErrorKind::BadRequest => 3,
            ErrorKind::Internal => 4,
            ErrorKind::Overloaded => 5,
            ErrorKind::Deadline => 6,
        }
    }

    fn from_code(c: u8) -> Option<ErrorKind> {
        match c {
            0 => Some(ErrorKind::Frame),
            1 => Some(ErrorKind::Version),
            2 => Some(ErrorKind::Decode),
            3 => Some(ErrorKind::BadRequest),
            4 => Some(ErrorKind::Internal),
            5 => Some(ErrorKind::Overloaded),
            6 => Some(ErrorKind::Deadline),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ErrorKind::Frame => "framing",
            ErrorKind::Version => "version",
            ErrorKind::Decode => "decode",
            ErrorKind::BadRequest => "bad-request",
            ErrorKind::Internal => "internal",
            ErrorKind::Overloaded => "overloaded",
            ErrorKind::Deadline => "deadline",
        }
    }

    /// Whether a client may transparently retry a request answered with
    /// this kind.  Protocol-level failures (framing, version skew,
    /// decode) are retryable because evals are pure and the bytes may
    /// simply have been damaged in transit; `Overloaded` is explicitly
    /// a "come back later" signal and `Deadline` an idle-connection
    /// reap (reconnect and resume).  `BadRequest` / `Internal` are
    /// terminal: resending identical bytes cannot change the answer.
    pub fn is_retryable(self) -> bool {
        matches!(
            self,
            ErrorKind::Frame
                | ErrorKind::Version
                | ErrorKind::Decode
                | ErrorKind::Overloaded
                | ErrorKind::Deadline
        )
    }
}

impl fmt::Display for ErrorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

// ---------------------------------------------------------------------------
// Message types
// ---------------------------------------------------------------------------

/// A machine spec reference: the compact id a client obtained from
/// [`Response::SpecInfo`], or a registered name resolved server-side.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpecRef {
    Id(u32),
    Name(String),
}

/// Which app to evaluate: a registered app name plus named integer
/// overrides of its default config (see [`crate::apps::scenario`]); an
/// empty parameter list is exactly `apps::by_name`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Scenario {
    pub app: String,
    pub params: Vec<(String, i64)>,
}

impl Scenario {
    /// The default-config scenario of a registered app.
    pub fn named(app: &str) -> Scenario {
        Scenario { app: app.to_string(), params: Vec::new() }
    }
}

/// One evaluation request as it travels the wire (the cross-process
/// image of [`crate::coordinator::EvalRequest`]; the server rebuilds
/// the `App` from the scenario).
#[derive(Debug, Clone, PartialEq)]
pub struct WireEvalRequest {
    pub spec: SpecRef,
    pub scenario: Scenario,
    pub dsl: String,
    pub mode: ExecMode,
    /// Scheduling priority, higher first
    /// ([`crate::coordinator::PRIORITY_NORMAL`] default).
    pub priority: u8,
    /// Client-stamped trace id; `0` means untraced.  Inert: it tags the
    /// span record and telemetry rider but never enters cache keys or
    /// scheduling.  Rides the wire as a *trailing optional* field (the
    /// Stats-tail zero-fill rule): elided when zero on a single `Eval`,
    /// and as a trailing id array on `EvalBatch` elided when all zero —
    /// so untraced traffic stays byte-identical to pre-trace peers.
    pub trace_id: u64,
}

/// Client-to-server messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Liveness / handshake probe.
    Ping,
    /// Evaluate one mapper; answered with [`Response::Feedback`].
    Eval(WireEvalRequest),
    /// Register (or alias) a machine spec; answered with
    /// [`Response::SpecInfo`].
    RegisterSpec { name: String, spec: MachineSpec },
    /// Look up a registered spec by name; answered with
    /// [`Response::SpecInfo`] or a `BadRequest` error.
    GetSpec { name: String },
    /// Snapshot of [`crate::coordinator::ServiceStats`]; answered with
    /// [`Response::Stats`].
    Stats,
    /// The human-readable `summary()` block; answered with
    /// [`Response::Summary`].
    Summary,
    /// Evaluate `1..=MAX_BATCH_ITEMS` mappers in one frame (one
    /// syscall round-trip for a grounded proposer's K candidates);
    /// answered with one [`Response::FeedbackBatch`] of equal length.
    /// A new tag: pre-batch peers classify it as a decode error and
    /// keep serving, so batching clients can fall back to
    /// frame-per-eval transparently.
    EvalBatch(Vec<WireEvalRequest>),
    /// Dump the peer's flight recorder (recent
    /// [`SpanRecord`]s, oldest first); answered with
    /// [`Response::TraceDump`].  The router answers with its shards'
    /// dumps concatenated ahead of its own.  A new tag, like
    /// `EvalBatch`: pre-trace peers classify it as a decode error and
    /// keep serving.
    TraceDump,
}

/// One entry of a [`Response::FeedbackBatch`], positionally matching
/// the [`Request::EvalBatch`] item it answers.  Items fail
/// *independently*: a shed or malformed candidate becomes a classified
/// per-item error (which the client may retry individually if
/// [`ErrorKind::is_retryable`]) without poisoning its batch-mates.
/// Unlike the top-level [`Response::Error`], the `retry_after_ms` hint
/// is always encoded — an item is not at the payload tail, so eliding
/// it would make the following items unparseable.
#[derive(Debug, Clone, PartialEq)]
pub enum BatchItem {
    Feedback(SystemFeedback),
    Error {
        kind: ErrorKind,
        msg: String,
        retry_after_ms: u64,
    },
}

/// Server-to-client messages, delivered strictly in request order.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Pong,
    Feedback(SystemFeedback),
    SpecInfo { id: u32, name: String, spec: MachineSpec },
    Stats(StatsSnapshot),
    Summary(String),
    /// A classified protocol- or request-level failure (evaluation
    /// failures travel as [`Response::Feedback`] carrying the usual
    /// compile/execution-error feedback, exactly like in-process).
    /// `retry_after_ms` is a server hint for [`ErrorKind::Overloaded`]
    /// (how long to back off before resubmitting); `0` means no hint
    /// and is elided on the wire so older decoders still parse.
    Error {
        kind: ErrorKind,
        msg: String,
        retry_after_ms: u64,
    },
    /// The answers to one [`Request::EvalBatch`], in item order and of
    /// equal length.  A new tag, like `EvalBatch`.
    FeedbackBatch(Vec<BatchItem>),
    /// The peer's flight-recorder contents, oldest first (the answer to
    /// [`Request::TraceDump`]).  A new tag, like `EvalBatch`.
    TraceDump(Vec<SpanRecord>),
}

// ---------------------------------------------------------------------------
// Primitive encode / decode
// ---------------------------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new(tag: u8) -> Enc {
        Enc { buf: vec![WIRE_VERSION, tag] }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    /// Check the version byte and position the cursor on the body;
    /// returns the message tag.
    fn new(payload: &'a [u8]) -> Result<(u8, Dec<'a>), DecodeError> {
        if payload.len() < 2 {
            return Err(DecodeError::Truncated);
        }
        if payload[0] != WIRE_VERSION {
            return Err(DecodeError::Version(payload[0]));
        }
        Ok((payload[1], Dec { buf: payload, pos: 2 }))
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.buf.len() - self.pos < n {
            return Err(DecodeError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take(1)?[0])
    }

    fn bool(&mut self) -> Result<bool, DecodeError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(DecodeError::Invalid("bool")),
        }
    }

    fn u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, DecodeError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i64(&mut self) -> Result<i64, DecodeError> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, DecodeError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str(&mut self) -> Result<String, DecodeError> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        std::str::from_utf8(bytes)
            .map(str::to_string)
            .map_err(|_| DecodeError::Utf8)
    }

    /// Bytes left in the payload — lets a decoder accept an older,
    /// shorter payload shape by defaulting fields appended since.
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// The payload must be fully consumed — trailing garbage is a
    /// decode error, not silently ignored bytes.
    fn finish(self) -> Result<(), DecodeError> {
        let extra = self.buf.len() - self.pos;
        if extra == 0 {
            Ok(())
        } else {
            Err(DecodeError::Trailing(extra))
        }
    }
}

// ---------------------------------------------------------------------------
// Domain-type codecs
// ---------------------------------------------------------------------------

fn enc_mode(e: &mut Enc, m: ExecMode) {
    e.u8(match m {
        ExecMode::BulkSync => 0,
        ExecMode::Serialized => 1,
        ExecMode::OutOfOrder => 2,
    });
}

fn dec_mode(d: &mut Dec<'_>) -> Result<ExecMode, DecodeError> {
    match d.u8()? {
        0 => Ok(ExecMode::BulkSync),
        1 => Ok(ExecMode::Serialized),
        2 => Ok(ExecMode::OutOfOrder),
        t => Err(DecodeError::UnknownTag("exec mode", t)),
    }
}

fn enc_spec_ref(e: &mut Enc, s: &SpecRef) {
    match s {
        SpecRef::Id(i) => {
            e.u8(0);
            e.u32(*i);
        }
        SpecRef::Name(n) => {
            e.u8(1);
            e.str(n);
        }
    }
}

fn dec_spec_ref(d: &mut Dec<'_>) -> Result<SpecRef, DecodeError> {
    match d.u8()? {
        0 => Ok(SpecRef::Id(d.u32()?)),
        1 => Ok(SpecRef::Name(d.str()?)),
        t => Err(DecodeError::UnknownTag("spec ref", t)),
    }
}

fn enc_scenario(e: &mut Enc, s: &Scenario) {
    e.str(&s.app);
    e.u32(s.params.len() as u32);
    for (k, v) in &s.params {
        e.str(k);
        e.i64(*v);
    }
}

fn dec_scenario(d: &mut Dec<'_>) -> Result<Scenario, DecodeError> {
    let app = d.str()?;
    let n = d.u32()? as usize;
    let mut params = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        let k = d.str()?;
        let v = d.i64()?;
        params.push((k, v));
    }
    Ok(Scenario { app, params })
}

fn enc_machine_spec(e: &mut Enc, spec: &MachineSpec) {
    // exhaustive destructure: a new MachineSpec field fails to compile
    // here until the codec (and WIRE_VERSION) are updated
    let MachineSpec {
        name,
        nodes,
        gpus_per_node,
        cpus_per_node,
        omp_per_node,
        sockets_per_node,
        fbmem_capacity,
        zcmem_capacity,
        sysmem_capacity,
        rdma_capacity,
        gpu_gflops,
        cpu_gflops,
        omp_gflops,
        fbmem_bw,
        sysmem_bw,
        zcmem_gpu_bw,
        zcmem_cpu_bw,
        sockmem_bw,
        pcie_bw,
        pcie_lat_us,
        p2p_bw,
        nic_bw,
        nic_lat_us,
        gpu_launch_us,
        cpu_spawn_us,
        omp_spawn_us,
    } = spec;
    e.str(name);
    e.u64(*nodes as u64);
    e.u64(*gpus_per_node as u64);
    e.u64(*cpus_per_node as u64);
    e.u64(*omp_per_node as u64);
    e.u64(*sockets_per_node as u64);
    e.u64(*fbmem_capacity);
    e.u64(*zcmem_capacity);
    e.u64(*sysmem_capacity);
    e.u64(*rdma_capacity);
    e.f64(*gpu_gflops);
    e.f64(*cpu_gflops);
    e.f64(*omp_gflops);
    e.f64(*fbmem_bw);
    e.f64(*sysmem_bw);
    e.f64(*zcmem_gpu_bw);
    e.f64(*zcmem_cpu_bw);
    e.f64(*sockmem_bw);
    e.f64(*pcie_bw);
    e.f64(*pcie_lat_us);
    e.f64(*p2p_bw);
    e.f64(*nic_bw);
    e.f64(*nic_lat_us);
    e.f64(*gpu_launch_us);
    e.f64(*cpu_spawn_us);
    e.f64(*omp_spawn_us);
}

fn dec_machine_spec(d: &mut Dec<'_>) -> Result<MachineSpec, DecodeError> {
    Ok(MachineSpec {
        name: d.str()?,
        nodes: d.u64()? as usize,
        gpus_per_node: d.u64()? as usize,
        cpus_per_node: d.u64()? as usize,
        omp_per_node: d.u64()? as usize,
        sockets_per_node: d.u64()? as usize,
        fbmem_capacity: d.u64()?,
        zcmem_capacity: d.u64()?,
        sysmem_capacity: d.u64()?,
        rdma_capacity: d.u64()?,
        gpu_gflops: d.f64()?,
        cpu_gflops: d.f64()?,
        omp_gflops: d.f64()?,
        fbmem_bw: d.f64()?,
        sysmem_bw: d.f64()?,
        zcmem_gpu_bw: d.f64()?,
        zcmem_cpu_bw: d.f64()?,
        sockmem_bw: d.f64()?,
        pcie_bw: d.f64()?,
        pcie_lat_us: d.f64()?,
        p2p_bw: d.f64()?,
        nic_bw: d.f64()?,
        nic_lat_us: d.f64()?,
        gpu_launch_us: d.f64()?,
        cpu_spawn_us: d.f64()?,
        omp_spawn_us: d.f64()?,
    })
}

fn enc_profile(e: &mut Enc, p: &PerfProfile) {
    let PerfProfile {
        engine,
        critical_path_s,
        critical_tasks,
        total_tasks,
        bottlenecks,
        mean_idle,
        worst_idle,
        worst_idle_proc,
        mean_slack_s,
        zero_slack_tasks,
    } = p;
    e.str(engine);
    e.f64(*critical_path_s);
    e.u64(*critical_tasks as u64);
    e.u64(*total_tasks as u64);
    e.u32(bottlenecks.len() as u32);
    for b in bottlenecks {
        let CritEntry { task, instances, seconds, share } = b;
        e.str(task);
        e.u64(*instances as u64);
        e.f64(*seconds);
        e.f64(*share);
    }
    e.f64(*mean_idle);
    e.f64(*worst_idle);
    e.str(worst_idle_proc);
    e.f64(*mean_slack_s);
    e.u64(*zero_slack_tasks as u64);
}

fn dec_profile(d: &mut Dec<'_>) -> Result<PerfProfile, DecodeError> {
    // `engine` is `&'static str` in-process; map the known names back
    let engine = match d.str()?.as_str() {
        "serialized" => "serialized",
        "out-of-order" => "out-of-order",
        "bulk-sync" => "bulk-sync",
        _ => return Err(DecodeError::Invalid("profile engine")),
    };
    let critical_path_s = d.f64()?;
    let critical_tasks = d.u64()? as usize;
    let total_tasks = d.u64()? as usize;
    let n = d.u32()? as usize;
    let mut bottlenecks = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        bottlenecks.push(CritEntry {
            task: d.str()?,
            instances: d.u64()? as usize,
            seconds: d.f64()?,
            share: d.f64()?,
        });
    }
    Ok(PerfProfile {
        engine,
        critical_path_s,
        critical_tasks,
        total_tasks,
        bottlenecks,
        mean_idle: d.f64()?,
        worst_idle: d.f64()?,
        worst_idle_proc: d.str()?,
        mean_slack_s: d.f64()?,
        zero_slack_tasks: d.u64()? as usize,
    })
}

fn enc_feedback(e: &mut Enc, fb: &SystemFeedback) {
    match fb {
        SystemFeedback::CompileError(msg) => {
            e.u8(0);
            e.str(msg);
        }
        SystemFeedback::ExecutionError(msg) => {
            e.u8(1);
            e.str(msg);
        }
        SystemFeedback::Performance { line, value, profile, telemetry: _ } => {
            // telemetry is *not* body material: feedback sits
            // mid-payload in batches, so the rider travels as the
            // Feedback payload tail / the FeedbackBatch trailing array
            e.u8(2);
            e.str(line);
            e.f64(*value);
            match profile {
                None => e.bool(false),
                Some(p) => {
                    e.bool(true);
                    enc_profile(e, p);
                }
            }
        }
    }
}

fn dec_feedback(d: &mut Dec<'_>) -> Result<SystemFeedback, DecodeError> {
    match d.u8()? {
        0 => Ok(SystemFeedback::CompileError(d.str()?)),
        1 => Ok(SystemFeedback::ExecutionError(d.str()?)),
        2 => {
            let line = d.str()?;
            let value = d.f64()?;
            let profile = if d.bool()? { Some(dec_profile(d)?) } else { None };
            // the top-level decoder re-attaches a telemetry tail
            Ok(SystemFeedback::Performance { line, value, profile, telemetry: None })
        }
        t => Err(DecodeError::UnknownTag("feedback", t)),
    }
}

/// The fixed 17-byte telemetry rider of a traced feedback: queue wait,
/// cache-path code, and simulation time of *this* serving.
fn enc_telemetry(e: &mut Enc, t: &EvalTelemetry) {
    let EvalTelemetry { queue_ns, cache_path, sim_ns } = t;
    e.u64(*queue_ns);
    e.u8(*cache_path);
    e.u64(*sim_ns);
}

fn dec_telemetry(d: &mut Dec<'_>) -> Result<EvalTelemetry, DecodeError> {
    Ok(EvalTelemetry {
        queue_ns: d.u64()?,
        cache_path: d.u8()?,
        sim_ns: d.u64()?,
    })
}

/// One flight-recorder span on the wire: identity, outcome, wall time,
/// then its stage list (count-prefixed; mid-payload, so never elided).
fn enc_span(e: &mut Enc, s: &SpanRecord) {
    let SpanRecord { trace_id, cache_path, outcome, total_ns, stages } = s;
    e.u64(*trace_id);
    e.u8(*cache_path);
    e.u8(*outcome);
    e.u64(*total_ns);
    e.u32(stages.len() as u32);
    for st in stages {
        let StageSpan { stage, start_ns, dur_ns } = st;
        e.u8(*stage);
        e.u64(*start_ns);
        e.u64(*dur_ns);
    }
}

fn dec_span(d: &mut Dec<'_>) -> Result<SpanRecord, DecodeError> {
    let trace_id = d.u64()?;
    let cache_path = d.u8()?;
    let outcome = d.u8()?;
    let total_ns = d.u64()?;
    let n = d.u32()? as usize;
    // a span passes through a bounded pipeline; a count beyond any real
    // stage list is hostile and rejected before allocation
    if n > MAX_BATCH_ITEMS {
        return Err(DecodeError::Invalid("span stage count"));
    }
    let mut stages = Vec::with_capacity(n);
    for _ in 0..n {
        stages.push(StageSpan {
            stage: d.u8()?,
            start_ns: d.u64()?,
            dur_ns: d.u64()?,
        });
    }
    Ok(SpanRecord { trace_id, cache_path, outcome, total_ns, stages })
}

fn enc_eval_req(e: &mut Enc, q: &WireEvalRequest) {
    // trace_id is *not* body material: it rides the payload tail of a
    // single Eval (elided when 0) or the trailing id array of an
    // EvalBatch, because mid-payload fields cannot be optional
    let WireEvalRequest { spec, scenario, dsl, mode, priority, trace_id: _ } = q;
    enc_spec_ref(e, spec);
    enc_scenario(e, scenario);
    e.str(dsl);
    enc_mode(e, *mode);
    e.u8(*priority);
}

fn dec_eval_req(d: &mut Dec<'_>) -> Result<WireEvalRequest, DecodeError> {
    Ok(WireEvalRequest {
        spec: dec_spec_ref(d)?,
        scenario: dec_scenario(d)?,
        dsl: d.str()?,
        mode: dec_mode(d)?,
        priority: d.u8()?,
        // zero-filled here; the top-level decoder overwrites it from
        // the payload tail when the client stamped one
        trace_id: 0,
    })
}

/// Decode and validate a batch item count: empty batches and counts
/// over [`MAX_BATCH_ITEMS`] are rejected here, *before* any per-item
/// allocation, so a hostile count prefix cannot reserve memory.
fn dec_batch_len(d: &mut Dec<'_>) -> Result<usize, DecodeError> {
    let n = d.u32()? as usize;
    if n == 0 {
        return Err(DecodeError::Invalid("empty batch"));
    }
    if n > MAX_BATCH_ITEMS {
        return Err(DecodeError::Invalid("batch item count"));
    }
    Ok(n)
}

fn enc_batch_item(e: &mut Enc, item: &BatchItem) {
    match item {
        BatchItem::Feedback(fb) => {
            e.u8(0);
            enc_feedback(e, fb);
        }
        BatchItem::Error { kind, msg, retry_after_ms } => {
            e.u8(1);
            e.u8(kind.code());
            e.str(msg);
            // always encoded (never elided like the top-level Error
            // hint): mid-payload fields cannot be optional
            e.u64(*retry_after_ms);
        }
    }
}

fn dec_batch_item(d: &mut Dec<'_>) -> Result<BatchItem, DecodeError> {
    match d.u8()? {
        0 => Ok(BatchItem::Feedback(dec_feedback(d)?)),
        1 => {
            let kind =
                ErrorKind::from_code(d.u8()?).ok_or(DecodeError::Invalid("error kind"))?;
            let msg = d.str()?;
            let retry_after_ms = d.u64()?;
            Ok(BatchItem::Error { kind, msg, retry_after_ms })
        }
        t => Err(DecodeError::UnknownTag("batch item", t)),
    }
}

fn enc_snapshot(e: &mut Enc, s: &StatsSnapshot) {
    let StatsSnapshot {
        evals,
        cache_hits,
        decision_hits,
        point_tasks,
        eval_ns,
        submitted,
        completed,
        plan_builds,
        plan_hits,
        policy_compiles,
        policy_hits,
        evicted_feedback,
        evicted_plans,
        evicted_policies,
        evicted_decisions,
        max_queue_depth,
        batch_occupancy,
        delta_evals,
        spliced_point_tasks,
        dirty_fallbacks,
        shed_requests,
        reaped_connections,
        refused_connections,
        retries,
        reconnects,
        specs,
        priorities,
        shards,
        stage_hists,
    } = s;
    e.u64(*evals);
    e.u64(*cache_hits);
    e.u64(*decision_hits);
    e.u64(*point_tasks);
    e.u64(*eval_ns);
    e.u64(*submitted);
    e.u64(*completed);
    e.u64(*plan_builds);
    e.u64(*plan_hits);
    e.u64(*policy_compiles);
    e.u64(*policy_hits);
    e.u64(*evicted_feedback);
    e.u64(*evicted_plans);
    e.u64(*evicted_policies);
    e.u64(*evicted_decisions);
    e.u64(*max_queue_depth);
    e.f64(*batch_occupancy);
    e.u32(specs.len() as u32);
    for sp in specs {
        let SpecSnapshot { name, evals, cache_hits } = sp;
        e.str(name);
        e.u64(*evals);
        e.u64(*cache_hits);
    }
    e.u32(priorities.len() as u32);
    for p in priorities {
        let PrioritySnapshot { priority, submitted, max_depth, queued } = p;
        e.u8(*priority);
        e.u64(*submitted);
        e.u64(*max_depth);
        e.u64(*queued);
    }
    // delta counters (PR 6), fault counters (PR 7), and the admission
    // counter (PR 8) ride at the tail so pre-delta decoders fail with a
    // clean Trailing error (and this decoder zero-fills their absence,
    // field by field)
    e.u64(*delta_evals);
    e.u64(*spliced_point_tasks);
    e.u64(*dirty_fallbacks);
    e.u64(*shed_requests);
    e.u64(*reaped_connections);
    e.u64(*retries);
    e.u64(*reconnects);
    e.u64(*refused_connections);
    // the fleet tail (PR 9): per-shard sections of a router-aggregated
    // snapshot, after every scalar tail field.  Elided entirely when
    // empty, so a single server's snapshot stays byte-identical with
    // pre-fleet peers; when present, a pre-fleet decoder fails with a
    // clean Trailing error and this decoder zero-fills its absence.
    // The histogram tail (PR 10) sits *after* the shard section, so a
    // snapshot carrying histograms must encode the shard count even
    // when zero — the shard section is no longer at the tail once
    // something follows it.  Both empty → both elided (byte-identical
    // to the PR 9 shape).
    if shards.is_empty() && stage_hists.is_empty() {
        return;
    }
    e.u32(shards.len() as u32);
    for sh in shards {
        let ShardSnapshot {
            addr,
            state,
            routed,
            evals,
            cache_hits,
            decision_hits,
            submitted,
            completed,
            shed_requests,
            max_queue_depth,
        } = sh;
        e.str(addr);
        e.u8(*state);
        e.u64(*routed);
        e.u64(*evals);
        e.u64(*cache_hits);
        e.u64(*decision_hits);
        e.u64(*submitted);
        e.u64(*completed);
        e.u64(*shed_requests);
        e.u64(*max_queue_depth);
    }
    // the histogram tail (PR 10): per-stage latency histograms, elided
    // when empty so histogram-free fleet snapshots stay byte-identical
    // with PR 9 peers (which then fail with a clean Trailing error on
    // histogram-bearing payloads, per the tail rule)
    if stage_hists.is_empty() {
        return;
    }
    e.u32(stage_hists.len() as u32);
    for h in stage_hists {
        let StageHistSnapshot { stage, hist } = h;
        e.u8(*stage);
        e.u32(hist.buckets.len() as u32);
        for b in &hist.buckets {
            e.u64(*b);
        }
    }
}

fn dec_snapshot(d: &mut Dec<'_>) -> Result<StatsSnapshot, DecodeError> {
    let evals = d.u64()?;
    let cache_hits = d.u64()?;
    let decision_hits = d.u64()?;
    let point_tasks = d.u64()?;
    let eval_ns = d.u64()?;
    let submitted = d.u64()?;
    let completed = d.u64()?;
    let plan_builds = d.u64()?;
    let plan_hits = d.u64()?;
    let policy_compiles = d.u64()?;
    let policy_hits = d.u64()?;
    let evicted_feedback = d.u64()?;
    let evicted_plans = d.u64()?;
    let evicted_policies = d.u64()?;
    let evicted_decisions = d.u64()?;
    let max_queue_depth = d.u64()?;
    let batch_occupancy = d.f64()?;
    let nspecs = d.u32()? as usize;
    let mut specs = Vec::with_capacity(nspecs.min(1024));
    for _ in 0..nspecs {
        specs.push(SpecSnapshot {
            name: d.str()?,
            evals: d.u64()?,
            cache_hits: d.u64()?,
        });
    }
    let nprio = d.u32()? as usize;
    let mut priorities = Vec::with_capacity(nprio.min(1024));
    for _ in 0..nprio {
        priorities.push(PrioritySnapshot {
            priority: d.u8()?,
            submitted: d.u64()?,
            max_depth: d.u64()?,
            queued: d.u64()?,
        });
    }
    // tail fields appended across revisions (delta counters, then the
    // fault-tolerance counters); each zero-fills independently so any
    // older peer's shorter payload — pre-delta or pre-fault — decodes
    // cleanly instead of panicking
    let mut tail = || -> Result<u64, DecodeError> {
        if d.remaining() > 0 { d.u64() } else { Ok(0) }
    };
    let delta_evals = tail()?;
    let spliced_point_tasks = tail()?;
    let dirty_fallbacks = tail()?;
    let shed_requests = tail()?;
    let reaped_connections = tail()?;
    let retries = tail()?;
    let reconnects = tail()?;
    let refused_connections = tail()?;
    // the fleet tail: a pre-fleet payload simply ends here (no shard
    // section, zero-fill rule → empty fleet); once the section is
    // present it decodes totally, so truncation inside it still errors
    let mut shards = Vec::new();
    let mut stage_hists = Vec::new();
    if d.remaining() > 0 {
        let nshards = d.u32()? as usize;
        shards.reserve(nshards.min(1024));
        for _ in 0..nshards {
            shards.push(ShardSnapshot {
                addr: d.str()?,
                state: d.u8()?,
                routed: d.u64()?,
                evals: d.u64()?,
                cache_hits: d.u64()?,
                decision_hits: d.u64()?,
                submitted: d.u64()?,
                completed: d.u64()?,
                shed_requests: d.u64()?,
                max_queue_depth: d.u64()?,
            });
        }
        // the histogram tail: a pre-histogram payload ends after its
        // shard entries (zero-fill rule → no histograms); once the
        // section starts it decodes totally
        if d.remaining() > 0 {
            let nh = d.u32()? as usize;
            stage_hists.reserve(nh.min(256));
            for _ in 0..nh {
                let stage = d.u8()?;
                let nb = d.u32()? as usize;
                // buckets are log2 of a u64, hard-capped by layout;
                // anything wider is hostile, not a newer peer
                if nb > BUCKETS {
                    return Err(DecodeError::Invalid("histogram bucket count"));
                }
                let mut buckets = Vec::with_capacity(nb);
                for _ in 0..nb {
                    buckets.push(d.u64()?);
                }
                stage_hists
                    .push(StageHistSnapshot { stage, hist: HistSnapshot { buckets } });
            }
        }
    }
    Ok(StatsSnapshot {
        evals,
        cache_hits,
        decision_hits,
        point_tasks,
        eval_ns,
        submitted,
        completed,
        plan_builds,
        plan_hits,
        policy_compiles,
        policy_hits,
        evicted_feedback,
        evicted_plans,
        evicted_policies,
        evicted_decisions,
        max_queue_depth,
        batch_occupancy,
        delta_evals,
        spliced_point_tasks,
        dirty_fallbacks,
        shed_requests,
        reaped_connections,
        refused_connections,
        retries,
        reconnects,
        specs,
        priorities,
        shards,
        stage_hists,
    })
}

// ---------------------------------------------------------------------------
// Top-level messages
// ---------------------------------------------------------------------------

impl Request {
    /// Serialize into one frame payload (`[version][tag][body]`).
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Request::Ping => Enc::new(0).buf,
            Request::Eval(q) => {
                let mut e = Enc::new(1);
                enc_eval_req(&mut e, q);
                // trace id at the payload tail, elided when untraced:
                // untraced frames stay byte-identical to pre-trace
                // peers, which classify traced ones as clean Trailing
                if q.trace_id != 0 {
                    e.u64(q.trace_id);
                }
                e.buf
            }
            Request::RegisterSpec { name, spec } => {
                let mut e = Enc::new(2);
                e.str(name);
                enc_machine_spec(&mut e, spec);
                e.buf
            }
            Request::GetSpec { name } => {
                let mut e = Enc::new(3);
                e.str(name);
                e.buf
            }
            Request::Stats => Enc::new(4).buf,
            Request::Summary => Enc::new(5).buf,
            Request::EvalBatch(items) => {
                let mut e = Enc::new(6);
                e.u32(items.len() as u32);
                for q in items {
                    enc_eval_req(&mut e, q);
                }
                // per-item trace ids as one trailing array (items are
                // mid-payload, so their own tails cannot be optional);
                // elided when every item is untraced
                if items.iter().any(|q| q.trace_id != 0) {
                    for q in items {
                        e.u64(q.trace_id);
                    }
                }
                e.buf
            }
            Request::TraceDump => Enc::new(7).buf,
        }
    }

    /// Total inverse of [`Request::encode`].
    pub fn decode(payload: &[u8]) -> Result<Request, DecodeError> {
        let (tag, mut d) = Dec::new(payload)?;
        let req = match tag {
            0 => Request::Ping,
            1 => {
                let mut q = dec_eval_req(&mut d)?;
                if d.remaining() > 0 {
                    q.trace_id = d.u64()?;
                }
                Request::Eval(q)
            }
            2 => Request::RegisterSpec {
                name: d.str()?,
                spec: dec_machine_spec(&mut d)?,
            },
            3 => Request::GetSpec { name: d.str()? },
            4 => Request::Stats,
            5 => Request::Summary,
            6 => {
                let n = dec_batch_len(&mut d)?;
                let mut items = Vec::with_capacity(n);
                for _ in 0..n {
                    items.push(dec_eval_req(&mut d)?);
                }
                // trailing trace-id array (zero-fill rule: absent on
                // pre-trace and untraced payloads)
                if d.remaining() > 0 {
                    for q in &mut items {
                        q.trace_id = d.u64()?;
                    }
                }
                Request::EvalBatch(items)
            }
            7 => Request::TraceDump,
            t => return Err(DecodeError::UnknownTag("request", t)),
        };
        d.finish()?;
        Ok(req)
    }
}

impl Response {
    /// Serialize into one frame payload (`[version][tag][body]`).
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Response::Pong => Enc::new(0).buf,
            Response::Feedback(fb) => {
                let mut e = Enc::new(1);
                enc_feedback(&mut e, fb);
                // telemetry rider at the payload tail, elided when the
                // serving path attached none — rider-free frames stay
                // byte-identical to pre-trace peers
                if let Some(t) = fb.telemetry() {
                    enc_telemetry(&mut e, t);
                }
                e.buf
            }
            Response::SpecInfo { id, name, spec } => {
                let mut e = Enc::new(2);
                e.u32(*id);
                e.str(name);
                enc_machine_spec(&mut e, spec);
                e.buf
            }
            Response::Stats(s) => {
                let mut e = Enc::new(3);
                enc_snapshot(&mut e, s);
                e.buf
            }
            Response::Summary(s) => {
                let mut e = Enc::new(4);
                e.str(s);
                e.buf
            }
            Response::Error { kind, msg, retry_after_ms } => {
                let mut e = Enc::new(5);
                e.u8(kind.code());
                e.str(msg);
                // hint rides at the tail, elided when absent, so the
                // pre-overload decoder shape still parses this payload
                if *retry_after_ms != 0 {
                    e.u64(*retry_after_ms);
                }
                e.buf
            }
            Response::FeedbackBatch(items) => {
                let mut e = Enc::new(6);
                e.u32(items.len() as u32);
                for item in items {
                    enc_batch_item(&mut e, item);
                }
                // per-item telemetry riders as one trailing array
                // (presence byte + fixed rider), elided when no item
                // carries one
                let any = items.iter().any(|i| {
                    matches!(i, BatchItem::Feedback(fb) if fb.telemetry().is_some())
                });
                if any {
                    for item in items {
                        match item {
                            BatchItem::Feedback(fb) => match fb.telemetry() {
                                Some(t) => {
                                    e.u8(1);
                                    enc_telemetry(&mut e, t);
                                }
                                None => e.u8(0),
                            },
                            BatchItem::Error { .. } => e.u8(0),
                        }
                    }
                }
                e.buf
            }
            Response::TraceDump(spans) => {
                let mut e = Enc::new(7);
                e.u32(spans.len() as u32);
                for s in spans {
                    enc_span(&mut e, s);
                }
                e.buf
            }
        }
    }

    /// Total inverse of [`Response::encode`].
    pub fn decode(payload: &[u8]) -> Result<Response, DecodeError> {
        let (tag, mut d) = Dec::new(payload)?;
        let resp = match tag {
            0 => Response::Pong,
            1 => {
                let mut fb = dec_feedback(&mut d)?;
                if d.remaining() > 0 {
                    let t = dec_telemetry(&mut d)?;
                    fb.set_telemetry(t);
                }
                Response::Feedback(fb)
            }
            2 => Response::SpecInfo {
                id: d.u32()?,
                name: d.str()?,
                spec: dec_machine_spec(&mut d)?,
            },
            3 => Response::Stats(dec_snapshot(&mut d)?),
            4 => Response::Summary(d.str()?),
            5 => {
                let kind = ErrorKind::from_code(d.u8()?)
                    .ok_or(DecodeError::Invalid("error kind"))?;
                let msg = d.str()?;
                let retry_after_ms = if d.remaining() > 0 { d.u64()? } else { 0 };
                Response::Error { kind, msg, retry_after_ms }
            }
            6 => {
                let n = dec_batch_len(&mut d)?;
                let mut items = Vec::with_capacity(n);
                for _ in 0..n {
                    items.push(dec_batch_item(&mut d)?);
                }
                // trailing telemetry array (zero-fill rule: absent on
                // pre-trace and rider-free payloads)
                if d.remaining() > 0 {
                    for item in &mut items {
                        if d.u8()? == 1 {
                            let t = dec_telemetry(&mut d)?;
                            if let BatchItem::Feedback(fb) = item {
                                fb.set_telemetry(t);
                            }
                        }
                    }
                }
                Response::FeedbackBatch(items)
            }
            7 => {
                let n = d.u32()? as usize;
                let mut spans = Vec::with_capacity(n.min(MAX_BATCH_ITEMS));
                for _ in 0..n {
                    spans.push(dec_span(&mut d)?);
                }
                Response::TraceDump(spans)
            }
            t => return Err(DecodeError::UnknownTag("response", t)),
        };
        d.finish()?;
        Ok(resp)
    }

    /// Short variant name (diagnostics; avoids dumping whole payloads
    /// into error strings).
    pub fn kind_name(&self) -> &'static str {
        match self {
            Response::Pong => "pong",
            Response::Feedback(_) => "feedback",
            Response::SpecInfo { .. } => "spec-info",
            Response::Stats(_) => "stats",
            Response::Summary(_) => "summary",
            Response::Error { .. } => "error",
            Response::FeedbackBatch(_) => "feedback-batch",
            Response::TraceDump(_) => "trace-dump",
        }
    }
}

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

/// Fold of the FNV-1a hash of a frame payload — the 4-byte integrity
/// trailer every frame carries so in-transit byte corruption is caught
/// at the framing layer (a mismatch is an unrecoverable framing error:
/// the damaged connection is torn down and the client's retry machinery
/// replays, keeping trajectories bit-identical even on a flaky link).
fn frame_checksum(payload: &[u8]) -> u32 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in payload {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0001_b3);
    }
    (h ^ (h >> 32)) as u32
}

/// Write one `len ++ payload ++ checksum` frame and flush.  `len`
/// counts the payload only; the trailing `u32 LE` is
/// [`frame_checksum`]` of the payload`.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    if payload.is_empty() || payload.len() > MAX_FRAME_LEN {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("refusing to write a {}-byte frame", payload.len()),
        ));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.write_all(&frame_checksum(payload).to_le_bytes())?;
    w.flush()
}

/// Read one frame payload.  `Ok(None)` is a clean end-of-stream (EOF at
/// a frame boundary); `Err` with [`io::ErrorKind::InvalidData`] is an
/// unrecoverable framing error (length prefix outside
/// `1..=MAX_FRAME_LEN`, checksum mismatch, or EOF partway through the
/// prefix — either way the stream cannot be resynchronized); other
/// errors are transport failures.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Option<Vec<u8>>> {
    // read the length prefix byte-wise so an EOF *inside* it (a peer
    // dying mid-frame) is distinguishable from a clean close *before*
    // it — read_exact cannot tell the two apart
    let mut len = [0u8; 4];
    let mut got = 0usize;
    while got < len.len() {
        match r.read(&mut len[got..]) {
            Ok(0) => {
                return if got == 0 {
                    Ok(None) // clean end-of-stream at a frame boundary
                } else {
                    Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("stream ended {got} bytes into a frame length prefix"),
                    ))
                };
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let n = u32::from_le_bytes(len) as usize;
    if n == 0 || n > MAX_FRAME_LEN {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {n} outside 1..={MAX_FRAME_LEN}"),
        ));
    }
    // grow the body buffer in bounded chunks as bytes actually arrive —
    // a hostile length prefix costs nothing until real payload follows
    const CHUNK: usize = 64 << 10;
    let mut buf = Vec::with_capacity(n.min(CHUNK));
    while buf.len() < n {
        let start = buf.len();
        buf.resize(n.min(start + CHUNK), 0);
        r.read_exact(&mut buf[start..])?;
    }
    let mut sum = [0u8; 4];
    r.read_exact(&mut sum)?;
    if u32::from_le_bytes(sum) != frame_checksum(&buf) {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "frame checksum mismatch (payload corrupted in transit)",
        ));
    }
    Ok(Some(buf))
}

/// One step of the incremental frame parser: what a buffer of bytes
/// read so far from a nonblocking socket amounts to.  This is
/// [`read_frame`]'s pull-based twin for the multiplexed server, which
/// cannot block a shared I/O thread waiting for one connection's
/// missing bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameStep {
    /// The buffer does not yet hold a whole frame; read more bytes and
    /// call again.
    Incomplete,
    /// One whole frame.  `consumed` is the total encoded size (length
    /// prefix + payload + checksum trailer) to drain from the front of
    /// the buffer before the next step.
    Frame { payload: Vec<u8>, consumed: usize },
    /// Unrecoverable framing damage (length prefix outside
    /// `1..=MAX_FRAME_LEN` or a checksum mismatch) — the stream cannot
    /// be resynchronized, mirroring [`read_frame`]'s `InvalidData`.
    Corrupt(String),
}

/// Parse at most one frame from the front of `buf` (bytes accumulated
/// from a nonblocking read).  Never consumes on its own: on
/// [`FrameStep::Frame`] the caller drains `consumed` bytes and may call
/// again — several pipelined frames can sit in one buffer.  A hostile
/// length prefix is rejected from the 4 prefix bytes alone, before any
/// payload is buffered or copied.
pub fn frame_step(buf: &[u8]) -> FrameStep {
    if buf.len() < 4 {
        return FrameStep::Incomplete;
    }
    let n = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    if n == 0 || n > MAX_FRAME_LEN {
        return FrameStep::Corrupt(format!("frame length {n} outside 1..={MAX_FRAME_LEN}"));
    }
    let total = 4 + n + 4;
    if buf.len() < total {
        return FrameStep::Incomplete;
    }
    let payload = &buf[4..4 + n];
    let sum = u32::from_le_bytes(buf[4 + n..total].try_into().unwrap());
    if sum != frame_checksum(payload) {
        return FrameStep::Corrupt(
            "frame checksum mismatch (payload corrupted in transit)".to_string(),
        );
    }
    FrameStep::Frame { payload: payload.to_vec(), consumed: total }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_profile() -> PerfProfile {
        PerfProfile {
            engine: "out-of-order",
            critical_path_s: 0.0295,
            critical_tasks: 40,
            total_tasks: 240,
            bottlenecks: vec![CritEntry {
                task: "calculate_new_currents".into(),
                instances: 10,
                seconds: 0.021,
                share: 0.71,
            }],
            mean_idle: 0.34,
            worst_idle: 0.61,
            worst_idle_proc: "GPU3@n1".into(),
            mean_slack_s: 0.0011,
            zero_slack_tasks: 40,
        }
    }

    fn roundtrip_req(r: &Request) {
        let bytes = r.encode();
        assert_eq!(bytes[0], WIRE_VERSION);
        assert_eq!(&Request::decode(&bytes).unwrap(), r, "request roundtrip");
    }

    fn roundtrip_resp(r: &Response) {
        let bytes = r.encode();
        assert_eq!(bytes[0], WIRE_VERSION);
        assert_eq!(&Response::decode(&bytes).unwrap(), r, "response roundtrip");
    }

    #[test]
    fn every_request_variant_roundtrips() {
        roundtrip_req(&Request::Ping);
        roundtrip_req(&Request::Eval(WireEvalRequest {
            spec: SpecRef::Name("p100_cluster".into()),
            scenario: Scenario {
                app: "stencil3d".into(),
                params: vec![("px".into(), 8), ("steps".into(), 3)],
            },
            dsl: "Task * GPU;\nRegion * * GPU FBMEM;\n".into(),
            mode: ExecMode::OutOfOrder,
            priority: 200,
            trace_id: 0,
        }));
        roundtrip_req(&Request::Eval(WireEvalRequest {
            spec: SpecRef::Id(3),
            scenario: Scenario::named("circuit"),
            dsl: String::new(),
            mode: ExecMode::BulkSync,
            priority: 0,
            trace_id: 0xDEAD_BEEF_0000_0001,
        }));
        roundtrip_req(&Request::TraceDump);
        roundtrip_req(&Request::RegisterSpec {
            name: "wide".into(),
            spec: MachineSpec::small(),
        });
        roundtrip_req(&Request::GetSpec { name: "small".into() });
        roundtrip_req(&Request::Stats);
        roundtrip_req(&Request::Summary);
        roundtrip_req(&Request::EvalBatch(vec![
            WireEvalRequest {
                spec: SpecRef::Id(1),
                scenario: Scenario::named("circuit"),
                dsl: "Task * GPU;\n".into(),
                mode: ExecMode::Serialized,
                priority: 128,
                trace_id: 0,
            },
            WireEvalRequest {
                spec: SpecRef::Name("p100_cluster".into()),
                scenario: Scenario {
                    app: "stencil3d".into(),
                    params: vec![("px".into(), 4)],
                },
                dsl: "Region * * GPU FBMEM;\n".into(),
                mode: ExecMode::OutOfOrder,
                priority: 255,
                trace_id: 7,
            },
        ]));
    }

    #[test]
    fn every_response_variant_roundtrips() {
        roundtrip_resp(&Response::Pong);
        roundtrip_resp(&Response::Feedback(SystemFeedback::CompileError(
            "mgpu not found".into(),
        )));
        roundtrip_resp(&Response::Feedback(SystemFeedback::ExecutionError(
            "Out of memory: FBMEM0@n0 capacity 1 bytes exceeded (need 2)".into(),
        )));
        roundtrip_resp(&Response::Feedback(SystemFeedback::Performance {
            line: "Performance Metric: Achieved throughput = 4877 GFLOPS".into(),
            value: 4877.25,
            profile: None,
            telemetry: None,
        }));
        roundtrip_resp(&Response::Feedback(SystemFeedback::Performance {
            line: "Performance Metric: Execution time is 0.0300s.".into(),
            value: 33.0,
            profile: Some(sample_profile()),
            telemetry: Some(EvalTelemetry {
                queue_ns: 12_345,
                cache_path: 5,
                sim_ns: 987_654,
            }),
        }));
        roundtrip_resp(&Response::SpecInfo {
            id: 1,
            name: "small".into(),
            spec: MachineSpec::small(),
        });
        roundtrip_resp(&Response::Stats(StatsSnapshot {
            evals: 10,
            cache_hits: 7,
            batch_occupancy: 1.75,
            delta_evals: 4,
            spliced_point_tasks: 9000,
            dirty_fallbacks: 2,
            shed_requests: 3,
            reaped_connections: 1,
            refused_connections: 5,
            retries: 6,
            reconnects: 2,
            specs: vec![SpecSnapshot {
                name: "p100_cluster".into(),
                evals: 10,
                cache_hits: 7,
            }],
            priorities: vec![PrioritySnapshot {
                priority: 128,
                submitted: 17,
                max_depth: 4,
                queued: 1,
            }],
            ..StatsSnapshot::default()
        }));
        roundtrip_resp(&Response::Summary("eval service: 3 evals\n".into()));
        roundtrip_resp(&Response::Error {
            kind: ErrorKind::BadRequest,
            msg: "unknown machine spec 'nope'".into(),
            retry_after_ms: 0,
        });
        roundtrip_resp(&Response::Error {
            kind: ErrorKind::Overloaded,
            msg: "queue at high-water mark (32 deep)".into(),
            retry_after_ms: 75,
        });
        roundtrip_resp(&Response::Error {
            kind: ErrorKind::Deadline,
            msg: "idle past the 300s connection deadline".into(),
            retry_after_ms: 0,
        });
        roundtrip_resp(&Response::FeedbackBatch(vec![
            BatchItem::Feedback(SystemFeedback::Performance {
                line: "Performance Metric: Execution time is 0.0300s.".into(),
                value: 33.0,
                profile: Some(sample_profile()),
                telemetry: None,
            }),
            BatchItem::Error {
                kind: ErrorKind::Overloaded,
                msg: "shed at the per-connection in-flight cap".into(),
                retry_after_ms: 25,
            },
            BatchItem::Feedback(SystemFeedback::CompileError("mgpu not found".into())),
            // unlike the top-level Error, a zero hint must roundtrip
            // mid-payload without being elided
            BatchItem::Error {
                kind: ErrorKind::BadRequest,
                msg: "unknown app 'nope'".into(),
                retry_after_ms: 0,
            },
        ]));
    }

    #[test]
    fn overloaded_hint_is_elided_when_zero_and_retryability_is_classified() {
        // a zero hint encodes to the pre-overload payload shape
        let without = Response::Error {
            kind: ErrorKind::Overloaded,
            msg: "shed".into(),
            retry_after_ms: 0,
        };
        let with = Response::Error {
            kind: ErrorKind::Overloaded,
            msg: "shed".into(),
            retry_after_ms: 50,
        };
        assert_eq!(without.encode().len() + 8, with.encode().len());
        assert_eq!(Response::decode(&without.encode()).unwrap(), without);
        assert_eq!(ErrorKind::from_code(5), Some(ErrorKind::Overloaded));
        assert_eq!(ErrorKind::Overloaded.name(), "overloaded");
        assert_eq!(ErrorKind::from_code(6), Some(ErrorKind::Deadline));
        assert_eq!(ErrorKind::Deadline.name(), "deadline");
        for kind in [
            ErrorKind::Frame,
            ErrorKind::Version,
            ErrorKind::Decode,
            ErrorKind::Overloaded,
            ErrorKind::Deadline,
        ] {
            assert!(kind.is_retryable(), "{kind} should be retryable");
        }
        for kind in [ErrorKind::BadRequest, ErrorKind::Internal] {
            assert!(!kind.is_retryable(), "{kind} should be terminal");
        }
    }

    #[test]
    fn scores_survive_the_wire_bit_identically() {
        // f64s travel as raw bits: subnormals, negatives, and values with
        // no short decimal representation must all come back bit-equal
        for value in [0.1 + 0.2, f64::MIN_POSITIVE, -1.0 / 3.0, 1e300] {
            let fb = SystemFeedback::Performance {
                line: "Performance Metric: Execution time is 0.0300s.".into(),
                value,
                profile: None,
                telemetry: None,
            };
            let bytes = Response::Feedback(fb.clone()).encode();
            match Response::decode(&bytes).unwrap() {
                Response::Feedback(got) => {
                    assert_eq!(got.score().to_bits(), value.to_bits());
                    assert_eq!(got, fb);
                }
                other => panic!("wrong variant {}", other.kind_name()),
            }
        }
    }

    #[test]
    fn version_skew_classifies_not_panics() {
        let mut bytes = Request::Ping.encode();
        bytes[0] = WIRE_VERSION + 1;
        let err = Request::decode(&bytes).unwrap_err();
        assert_eq!(err, DecodeError::Version(WIRE_VERSION + 1));
        assert_eq!(err.wire_kind(), ErrorKind::Version);
        assert!(err.to_string().contains("unsupported wire version"));
    }

    #[test]
    fn truncation_and_trailing_classify_not_panic() {
        let bytes = Request::GetSpec { name: "p100_cluster".into() }.encode();
        for cut in 0..bytes.len() {
            let err = Request::decode(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(err, DecodeError::Truncated | DecodeError::Version(_)),
                "cut at {cut}: unexpected {err:?}"
            );
        }
        let mut long = bytes.clone();
        long.push(0xEE);
        assert_eq!(Request::decode(&long).unwrap_err(), DecodeError::Trailing(1));
        assert_eq!(
            Request::decode(&[WIRE_VERSION, 0xFE]).unwrap_err(),
            DecodeError::UnknownTag("request", 0xFE)
        );
        assert_eq!(err_kind_of(&DecodeError::Truncated), ErrorKind::Decode);
    }

    fn err_kind_of(e: &DecodeError) -> ErrorKind {
        e.wire_kind()
    }

    #[test]
    fn older_stats_payloads_decode_with_zeroed_tail_counters() {
        // older peers' Stats payloads are exactly today's shape minus
        // trailing u64s: pre-admission peers lack the last one,
        // pre-fault peers the last five, pre-delta peers all eight —
        // every shape must decode cleanly, never panic
        let full = StatsSnapshot {
            evals: 11,
            cache_hits: 3,
            delta_evals: 5,
            spliced_point_tasks: 1234,
            dirty_fallbacks: 1,
            shed_requests: 7,
            reaped_connections: 2,
            refused_connections: 3,
            retries: 4,
            reconnects: 1,
            priorities: vec![PrioritySnapshot {
                priority: 128,
                submitted: 9,
                max_depth: 2,
                queued: 0,
            }],
            ..StatsSnapshot::default()
        };
        let bytes = Response::Stats(full.clone()).encode();
        let pre_admission = &bytes[..bytes.len() - 8];
        match Response::decode(pre_admission).unwrap() {
            Response::Stats(got) => assert_eq!(
                got,
                StatsSnapshot { refused_connections: 0, ..full.clone() }
            ),
            other => panic!("wrong variant {}", other.kind_name()),
        }
        let pre_fault = &bytes[..bytes.len() - 40];
        match Response::decode(pre_fault).unwrap() {
            Response::Stats(got) => assert_eq!(
                got,
                StatsSnapshot {
                    shed_requests: 0,
                    reaped_connections: 0,
                    refused_connections: 0,
                    retries: 0,
                    reconnects: 0,
                    ..full.clone()
                }
            ),
            other => panic!("wrong variant {}", other.kind_name()),
        }
        let pre_delta = &bytes[..bytes.len() - 64];
        match Response::decode(pre_delta).unwrap() {
            Response::Stats(got) => assert_eq!(
                got,
                StatsSnapshot {
                    delta_evals: 0,
                    spliced_point_tasks: 0,
                    dirty_fallbacks: 0,
                    shed_requests: 0,
                    reaped_connections: 0,
                    refused_connections: 0,
                    retries: 0,
                    reconnects: 0,
                    ..full
                }
            ),
            other => panic!("wrong variant {}", other.kind_name()),
        }
        // truncating inside any tail field still classifies (cuts on
        // field boundaries decode with the shorter-payload zero-fill)
        for cut in 1..64 {
            let short = &bytes[..bytes.len() - cut];
            if cut % 8 == 0 {
                assert!(
                    matches!(Response::decode(short), Ok(Response::Stats(_))),
                    "cut {cut}: field-boundary cut should zero-fill"
                );
            } else {
                let err = Response::decode(short).unwrap_err();
                assert!(
                    matches!(err, DecodeError::Truncated),
                    "cut {cut}: unexpected {err:?}"
                );
            }
        }
    }

    #[test]
    fn fleet_shard_tail_roundtrips_and_follows_the_tail_rules() {
        let fleet = StatsSnapshot {
            evals: 40,
            cache_hits: 60,
            submitted: 100,
            completed: 100,
            shards: vec![
                ShardSnapshot {
                    addr: "127.0.0.1:9401".into(),
                    state: 0,
                    routed: 61,
                    evals: 25,
                    cache_hits: 35,
                    decision_hits: 4,
                    submitted: 60,
                    completed: 60,
                    shed_requests: 1,
                    max_queue_depth: 7,
                },
                ShardSnapshot {
                    addr: "127.0.0.1:9402".into(),
                    state: 2,
                    routed: 40,
                    evals: 15,
                    cache_hits: 25,
                    decision_hits: 0,
                    submitted: 40,
                    completed: 40,
                    shed_requests: 0,
                    max_queue_depth: 3,
                },
            ],
            ..StatsSnapshot::default()
        };
        roundtrip_resp(&Response::Stats(fleet.clone()));

        // the empty fleet is elided: a single server's snapshot is
        // byte-identical to a pre-fleet peer's, so the tail-cut rules
        // of the test above keep holding for non-fleet payloads
        let single = StatsSnapshot { shards: Vec::new(), ..fleet.clone() };
        let single_bytes = Response::Stats(single.clone()).encode();
        let mut refetched = match Response::decode(&single_bytes).unwrap() {
            Response::Stats(s) => s,
            other => panic!("wrong variant {}", other.kind_name()),
        };
        assert_eq!(refetched, single);
        refetched.shards = fleet.shards.clone();
        assert!(
            Response::Stats(refetched).encode().len() > single_bytes.len(),
            "a populated fleet tail must extend the payload"
        );

        // a pre-fleet decoder's view of this payload ends before the
        // shard section, so cutting the whole section off must decode
        // to the same snapshot with an empty fleet (the zero-fill rule)
        let bytes = Response::Stats(fleet.clone()).encode();
        let section = bytes.len() - single_bytes.len();
        match Response::decode(&bytes[..bytes.len() - section]).unwrap() {
            Response::Stats(got) => assert_eq!(got, single),
            other => panic!("wrong variant {}", other.kind_name()),
        }

        // truncation *inside* the shard section is corruption, not an
        // older peer: it must classify as Truncated, never zero-fill
        for cut in 1..section {
            let err = Response::decode(&bytes[..bytes.len() - cut]).unwrap_err();
            assert!(
                matches!(err, DecodeError::Truncated),
                "cut {cut}: unexpected {err:?}"
            );
        }

        // bytes after the shard section violate the total-decode rule
        let mut trailing = bytes.clone();
        trailing.push(0xAB);
        assert!(matches!(
            Response::decode(&trailing).unwrap_err(),
            DecodeError::Trailing(1)
        ));
    }

    #[test]
    fn frames_roundtrip_and_reject_bad_lengths() {
        let payload = Request::Stats.encode();
        let mut wire = Vec::new();
        write_frame(&mut wire, &payload).unwrap();
        write_frame(&mut wire, &payload).unwrap();
        let mut r = wire.as_slice();
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), payload);
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), payload);
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF");

        // zero-length and oversized prefixes are unrecoverable framing
        let mut zero = 0u32.to_le_bytes().to_vec();
        zero.extend_from_slice(&payload);
        let err = read_frame(&mut zero.as_slice()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        let huge = ((MAX_FRAME_LEN + 1) as u32).to_le_bytes();
        let err = read_frame(&mut huge.as_slice()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // a hostile prefix claiming the maximum length costs no huge
        // up-front allocation and fails when the body never arrives
        let max_claim = (MAX_FRAME_LEN as u32).to_le_bytes();
        assert!(read_frame(&mut max_claim.as_slice()).is_err());
        assert!(write_frame(&mut Vec::new(), &[]).is_err());
    }

    #[test]
    fn corrupted_frames_fail_the_checksum_not_the_decoder() {
        let payload = Request::GetSpec { name: "p100_cluster".into() }.encode();
        let mut wire = Vec::new();
        write_frame(&mut wire, &payload).unwrap();
        // flip one payload byte: caught by the checksum trailer
        let mut bent = wire.clone();
        bent[4 + payload.len() / 2] ^= 0x40;
        let err = read_frame(&mut bent.as_slice()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("checksum"));
        // flip one checksum byte: same classification
        let mut tail = wire.clone();
        let last = tail.len() - 1;
        tail[last] ^= 0x01;
        let err = read_frame(&mut tail.as_slice()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // the pristine frame still reads back
        assert_eq!(read_frame(&mut wire.as_slice()).unwrap().unwrap(), payload);
    }

    #[test]
    fn batch_bounds_are_enforced_before_allocation() {
        // an empty batch is semantically impossible, both directions
        let empty_req: Vec<u8> = vec![WIRE_VERSION, 6, 0, 0, 0, 0];
        assert_eq!(
            Request::decode(&empty_req).unwrap_err(),
            DecodeError::Invalid("empty batch")
        );
        let empty_resp: Vec<u8> = vec![WIRE_VERSION, 6, 0, 0, 0, 0];
        assert_eq!(
            Response::decode(&empty_resp).unwrap_err(),
            DecodeError::Invalid("empty batch")
        );
        // a hostile count prefix claiming u32::MAX items must be
        // rejected from the 6 header bytes alone — if this path ever
        // allocated per-item first, the test box would feel it
        let mut huge = vec![WIRE_VERSION, 6];
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(
            Request::decode(&huge).unwrap_err(),
            DecodeError::Invalid("batch item count")
        );
        assert_eq!(
            Response::decode(&huge).unwrap_err(),
            DecodeError::Invalid("batch item count")
        );
        // one past the cap is rejected; the cap itself would read items
        let mut over = vec![WIRE_VERSION, 6];
        over.extend_from_slice(&((MAX_BATCH_ITEMS + 1) as u32).to_le_bytes());
        assert_eq!(
            Request::decode(&over).unwrap_err(),
            DecodeError::Invalid("batch item count")
        );
        // a plausible count whose items never arrive is a truncation
        let mut cut = vec![WIRE_VERSION, 6];
        cut.extend_from_slice(&2u32.to_le_bytes());
        assert_eq!(Request::decode(&cut).unwrap_err(), DecodeError::Truncated);
        // count mismatch (extra encoded item) is trailing garbage
        let two = Request::EvalBatch(vec![
            WireEvalRequest {
                spec: SpecRef::Id(0),
                scenario: Scenario::named("circuit"),
                dsl: String::new(),
                mode: ExecMode::Serialized,
                priority: 128,
                trace_id: 0,
            };
            2
        ]);
        let mut bytes = two.encode();
        bytes[2..6].copy_from_slice(&1u32.to_le_bytes());
        assert!(matches!(
            Request::decode(&bytes).unwrap_err(),
            DecodeError::Trailing(_)
        ));
    }

    #[test]
    fn frame_step_parses_incrementally_and_matches_read_frame() {
        let a = Request::Stats.encode();
        let b = Request::GetSpec { name: "p100_cluster".into() }.encode();
        let mut wire = Vec::new();
        write_frame(&mut wire, &a).unwrap();
        write_frame(&mut wire, &b).unwrap();
        // every strict prefix of the first frame is Incomplete
        let first_len = 4 + a.len() + 4;
        for cut in 0..first_len {
            assert_eq!(
                frame_step(&wire[..cut]),
                FrameStep::Incomplete,
                "prefix of {cut} bytes"
            );
        }
        // the whole buffer yields frame one, then (after draining
        // `consumed`) frame two, then Incomplete on the empty rest
        match frame_step(&wire) {
            FrameStep::Frame { payload, consumed } => {
                assert_eq!(payload, a);
                assert_eq!(consumed, first_len);
                match frame_step(&wire[consumed..]) {
                    FrameStep::Frame { payload, consumed } => {
                        assert_eq!(payload, b);
                        assert_eq!(consumed, 4 + b.len() + 4);
                    }
                    other => panic!("second step: {other:?}"),
                }
            }
            other => panic!("first step: {other:?}"),
        }
        assert_eq!(frame_step(&[]), FrameStep::Incomplete);
        // the same corruptions read_frame rejects are Corrupt here
        let zero = 0u32.to_le_bytes();
        assert!(matches!(frame_step(&zero), FrameStep::Corrupt(_)));
        let huge = ((MAX_FRAME_LEN + 1) as u32).to_le_bytes();
        assert!(matches!(frame_step(&huge), FrameStep::Corrupt(_)));
        let mut bent = wire.clone();
        bent[4 + a.len() / 2] ^= 0x40;
        match frame_step(&bent) {
            FrameStep::Corrupt(msg) => assert!(msg.contains("checksum")),
            other => panic!("corrupted step: {other:?}"),
        }
    }

    #[test]
    fn eval_trace_id_rides_the_tail_elided_when_zero() {
        let untraced = Request::Eval(WireEvalRequest {
            spec: SpecRef::Id(1),
            scenario: Scenario::named("circuit"),
            dsl: "Task * GPU;\n".into(),
            mode: ExecMode::Serialized,
            priority: 128,
            trace_id: 0,
        });
        let traced = match &untraced {
            Request::Eval(q) => {
                Request::Eval(WireEvalRequest { trace_id: 0xCAFE, ..q.clone() })
            }
            _ => unreachable!(),
        };
        let u = untraced.encode();
        let t = traced.encode();
        assert_eq!(u.len() + 8, t.len(), "trace id is exactly one trailing u64");
        assert_eq!(Request::decode(&u).unwrap(), untraced);
        assert_eq!(Request::decode(&t).unwrap(), traced);
        // a pre-trace decoder's view of the traced payload is the id
        // cut off: zero-fill back to untraced
        assert_eq!(Request::decode(&t[..t.len() - 8]).unwrap(), untraced);
        // truncation inside the tail classifies, never zero-fills
        for cut in 1..8 {
            assert_eq!(
                Request::decode(&t[..t.len() - cut]).unwrap_err(),
                DecodeError::Truncated,
                "cut {cut}"
            );
        }
    }

    #[test]
    fn batch_trace_ids_ride_a_trailing_array() {
        let mk = |trace_id: u64| WireEvalRequest {
            spec: SpecRef::Id(0),
            scenario: Scenario::named("circuit"),
            dsl: String::new(),
            mode: ExecMode::Serialized,
            priority: 128,
            trace_id,
        };
        let plain = Request::EvalBatch(vec![mk(0), mk(0), mk(0)]);
        let traced = Request::EvalBatch(vec![mk(5), mk(0), mk(9)]);
        let p = plain.encode();
        let t = traced.encode();
        assert_eq!(p.len() + 3 * 8, t.len(), "one trailing u64 per item, or none");
        assert_eq!(Request::decode(&t).unwrap(), traced);
        // the array is all-or-nothing: cutting it zero-fills every id
        assert_eq!(Request::decode(&t[..t.len() - 24]).unwrap(), plain);
        // cuts inside it classify
        for cut in [1usize, 8, 16, 23] {
            assert_eq!(
                Request::decode(&t[..t.len() - cut]).unwrap_err(),
                DecodeError::Truncated,
                "cut {cut}"
            );
        }
    }

    #[test]
    fn feedback_telemetry_rides_the_tail() {
        let telemetry =
            EvalTelemetry { queue_ns: 77, cache_path: 4, sim_ns: 123_456 };
        let mut fb = SystemFeedback::Performance {
            line: "Performance Metric: Execution time is 0.0300s.".into(),
            value: 33.0,
            profile: None,
            telemetry: None,
        };
        let bare = Response::Feedback(fb.clone()).encode();
        fb.set_telemetry(telemetry);
        let bytes = Response::Feedback(fb.clone()).encode();
        assert_eq!(bare.len() + 17, bytes.len(), "rider is 17 trailing bytes");
        match Response::decode(&bytes).unwrap() {
            Response::Feedback(got) => {
                assert_eq!(got.telemetry(), Some(&telemetry))
            }
            other => panic!("wrong variant {}", other.kind_name()),
        }
        // a pre-trace decoder's view: rider cut off → telemetry None
        match Response::decode(&bytes[..bytes.len() - 17]).unwrap() {
            Response::Feedback(got) => assert_eq!(got.telemetry(), None),
            other => panic!("wrong variant {}", other.kind_name()),
        }
        for cut in 1..17 {
            assert!(
                matches!(
                    Response::decode(&bytes[..bytes.len() - cut]).unwrap_err(),
                    DecodeError::Truncated
                ),
                "cut {cut}"
            );
        }
    }

    #[test]
    fn batch_telemetry_rides_a_trailing_presence_array() {
        let telemetry =
            EvalTelemetry { queue_ns: 9, cache_path: 1, sim_ns: 0 };
        let mut perf = SystemFeedback::Performance {
            line: "Performance Metric: Execution time is 0.0300s.".into(),
            value: 33.0,
            profile: None,
            telemetry: None,
        };
        let err_item = BatchItem::Error {
            kind: ErrorKind::Overloaded,
            msg: "shed".into(),
            retry_after_ms: 10,
        };
        let bare = Response::FeedbackBatch(vec![
            BatchItem::Feedback(perf.clone()),
            err_item.clone(),
            BatchItem::Feedback(SystemFeedback::CompileError("mgpu not found".into())),
        ]);
        let bare_bytes = bare.encode();
        perf.set_telemetry(telemetry);
        let traced = Response::FeedbackBatch(vec![
            BatchItem::Feedback(perf),
            err_item,
            BatchItem::Feedback(SystemFeedback::CompileError("mgpu not found".into())),
        ]);
        let traced_bytes = traced.encode();
        // one presence byte per item plus the single 17-byte rider
        assert_eq!(bare_bytes.len() + 3 + 17, traced_bytes.len());
        match Response::decode(&traced_bytes).unwrap() {
            Response::FeedbackBatch(items) => {
                assert_eq!(items.len(), 3);
                match &items[0] {
                    BatchItem::Feedback(got) => {
                        assert_eq!(got.telemetry(), Some(&telemetry))
                    }
                    other => panic!("wrong item {other:?}"),
                }
                match &items[2] {
                    BatchItem::Feedback(got) => assert_eq!(got.telemetry(), None),
                    other => panic!("wrong item {other:?}"),
                }
            }
            other => panic!("wrong variant {}", other.kind_name()),
        }
        // a pre-trace decoder's view: array cut off → no riders
        let cut = &traced_bytes[..traced_bytes.len() - (3 + 17)];
        match Response::decode(cut).unwrap() {
            Response::FeedbackBatch(items) => {
                for item in &items {
                    if let BatchItem::Feedback(fb) = item {
                        assert_eq!(fb.telemetry(), None);
                    }
                }
            }
            other => panic!("wrong variant {}", other.kind_name()),
        }
    }

    #[test]
    fn trace_dump_roundtrips_and_guards_hostile_counts() {
        roundtrip_resp(&Response::TraceDump(Vec::new()));
        roundtrip_resp(&Response::TraceDump(vec![
            SpanRecord::default(),
            SpanRecord {
                trace_id: 0xAB,
                cache_path: 5,
                outcome: 1,
                total_ns: 1_000_000,
                stages: vec![
                    StageSpan { stage: 3, start_ns: 0, dur_ns: 500 },
                    StageSpan { stage: 10, start_ns: 600, dur_ns: 900_000 },
                ],
            },
        ]));
        // a hostile per-span stage count fails before allocation
        let mut hostile = vec![WIRE_VERSION, 7];
        hostile.extend_from_slice(&1u32.to_le_bytes()); // one span
        hostile.extend_from_slice(&[0u8; 8]); // trace_id
        hostile.push(0); // cache_path
        hostile.push(0); // outcome
        hostile.extend_from_slice(&[0u8; 8]); // total_ns
        hostile.extend_from_slice(&u32::MAX.to_le_bytes()); // stage count
        assert_eq!(
            Response::decode(&hostile).unwrap_err(),
            DecodeError::Invalid("span stage count")
        );
    }

    #[test]
    fn stats_histogram_tail_roundtrips_and_follows_the_tail_rules() {
        let hists = vec![
            StageHistSnapshot {
                stage: 3,
                hist: HistSnapshot::of_samples(&[100, 2_000]),
            },
            StageHistSnapshot {
                stage: 10,
                hist: HistSnapshot::of_samples(&[1_000_000]),
            },
        ];
        // histograms without a fleet: the shard count is still encoded
        // (zero) because the hist section follows it
        let solo = StatsSnapshot {
            evals: 3,
            stage_hists: hists.clone(),
            ..StatsSnapshot::default()
        };
        roundtrip_resp(&Response::Stats(solo.clone()));
        // and riding behind a populated fleet tail
        let fleet = StatsSnapshot {
            shards: vec![ShardSnapshot {
                addr: "127.0.0.1:9401".into(),
                state: 0,
                routed: 3,
                evals: 3,
                cache_hits: 0,
                decision_hits: 0,
                submitted: 3,
                completed: 3,
                shed_requests: 0,
                max_queue_depth: 1,
            }],
            ..solo.clone()
        };
        roundtrip_resp(&Response::Stats(fleet.clone()));

        // a pre-histogram decoder's view ends after the shard entries:
        // cutting the hist section decodes to the histogram-free twin
        let bytes = Response::Stats(fleet.clone()).encode();
        let histless = StatsSnapshot { stage_hists: Vec::new(), ..fleet.clone() };
        let histless_bytes = Response::Stats(histless.clone()).encode();
        let section = bytes.len() - histless_bytes.len();
        match Response::decode(&bytes[..bytes.len() - section]).unwrap() {
            Response::Stats(got) => assert_eq!(got, histless),
            other => panic!("wrong variant {}", other.kind_name()),
        }
        // truncation inside the hist section is corruption, not an
        // older peer: it must classify, never zero-fill
        for cut in 1..section {
            assert!(
                matches!(
                    Response::decode(&bytes[..bytes.len() - cut]).unwrap_err(),
                    DecodeError::Truncated
                ),
                "cut {cut}"
            );
        }

        // both sections empty → both elided: byte-identical to the
        // pre-fleet payload shape
        let none = StatsSnapshot { evals: 3, ..StatsSnapshot::default() };
        let none_bytes = Response::Stats(none.clone()).encode();
        let solo_bytes = Response::Stats(solo.clone()).encode();
        assert!(solo_bytes.len() > none_bytes.len() + 8, "count words + entries");
        assert_eq!(Response::decode(&none_bytes).unwrap(), Response::Stats(none));

        // a hostile bucket count wider than the layout is rejected
        // (solo's tail starts where none's payload ends: shard count,
        // hist count, first stage byte, then the bucket count)
        let mut hostile = solo_bytes.clone();
        let off = none_bytes.len() + 4 + 4 + 1;
        hostile[off..off + 4]
            .copy_from_slice(&((BUCKETS + 1) as u32).to_le_bytes());
        assert_eq!(
            Response::decode(&hostile).unwrap_err(),
            DecodeError::Invalid("histogram bucket count")
        );
    }
}
