//! The sharded eval fabric: an [`EvalRouter`] speaks the existing wire
//! protocol on a front address and shards evaluation traffic across N
//! backend [`EvalServer`](super::EvalServer)s, so a fleet scales
//! throughput without diluting the caches that dominate ms/eval.
//!
//! # Cache-affinity routing
//!
//! Every eval frame is hashed to a 64-bit **affinity key**
//! ([`affinity_key`]) over its semantic identity — spec reference,
//! scenario (app + params), DSL source, and execution mode, but *not*
//! priority — with the shared FNV-1a primitive
//! ([`crate::util::hash`]).  The key lands on a consistent-hash ring
//! ([`HashRing`], [`RING_VNODES`] virtual nodes per shard), so
//! identical and re-submitted mappers always reach the shard whose
//! decision/plan/policy/feedback caches are already warm for them, and
//! a membership change moves only ~1/N of the keyspace instead of
//! reshuffling everything.  Batch frames are split into per-shard
//! sub-batches and re-joined in item order.
//!
//! # Replicated spec registries
//!
//! `RegisterSpec` fans out to every live shard and answers only when
//! all acked (any shard's refusal is the answer).  Acked registrations
//! are appended to a replay log, which [`EvalRouter::join_shard`]
//! replays into a joining shard before it takes ring traffic — so any
//! shard can serve any spec the fleet has seen.  Spec *ids* stay
//! aligned across shards as long as registrations flow through the
//! router (the shards preregister the built-in specs in the same
//! order); clients that must survive id skew can pin
//! [`SpecRef::Name`] refs instead.
//!
//! # Fleet membership
//!
//! A shard is `up` (routable), `draining` (no new work; in-flight
//! settling — [`EvalRouter::leave_shard`]), or `dead` (unreachable).
//! Death is detected on the backend link: a severed connection fails
//! its in-flight requests with a *retryable*
//! [`ErrorKind::Overloaded`] answer, the shard leaves the ring, and
//! the client's own [`RetryPolicy`](super::RetryPolicy) replays the
//! request — which now hashes onto a live shard.  Re-routing therefore
//! reuses the retry path that already exists for overload and chaos,
//! and evaluation purity makes the replayed answer bit-identical.
//!
//! # Fleet observability
//!
//! `Ping` answers router-side.  `Stats` fans out and folds the
//! per-shard snapshots through
//! [`StatsSnapshot::aggregate_fleet`] — counters sum, per-shard rates
//! ride in the snapshot's fleet tail under the zero-fill decode rule —
//! and `Summary` concatenates per-shard blocks under a fleet header.
//! The router keeps its own [`Telemetry`]: `route` (frame dispatch) and
//! `upstream` (backend round-trip) stage histograms merged into the
//! fleet `Stats` aggregate, plus a flight recorder that lands a
//! `rerouted` span for every traced request bounced off a dead shard.
//! `TraceDump` fans out too, answering every shard's spans concatenated
//! ahead of the router's own.
//!
//! # Limits
//!
//! The router multiplexes its front exactly like the server (same I/O
//! pool, slab, deadlines, and backpressure bounds) and funnels backend
//! traffic through `io_threads x` [`BACKEND_LANES`] connections per
//! shard, so one shard can hold at most
//! `io_threads * BACKEND_LANES * MAX_CONN_IN_FLIGHT` router-submitted
//! evaluations before its own connection-level shedding answers — a
//! bound the fleet loadtest stays well under.

use std::cell::RefCell;
use std::collections::hash_map::Entry;
use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{
    IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream,
    ToSocketAddrs,
};
use std::rc::Rc;
use std::sync::atomic::{
    AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering,
};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::{
    ShardContribution, StatsSnapshot, SHARD_DEAD, SHARD_DRAINING, SHARD_UP,
};
use crate::machine::MachineSpec;
use crate::obs::{
    merge_stage_hists, SpanBuilder, Stage, Telemetry, SPAN_REROUTED,
};
use crate::sim::ExecMode;
use crate::util::hash::{fnv1a, Fnv1a};

use super::proto::{
    self, BatchItem, ErrorKind, FrameStep, Request, Response, SpecRef,
    WireEvalRequest,
};
use super::server::{
    ServerConfig, MAX_PENDING_REPLIES, MAX_WRITE_BACKLOG, READ_BUDGET_PER_SCAN,
};

/// Virtual nodes per shard on the consistent-hash ring: enough that a
/// fleet of a handful of shards splits the keyspace within a few
/// percent of evenly, cheap enough that ring rebuilds (membership
/// changes only) stay microseconds.
pub const RING_VNODES: usize = 64;

/// Backend connections each I/O thread keeps per shard.  One would
/// serialize a whole thread's traffic behind a single connection's
/// [`MAX_CONN_IN_FLIGHT`](super::server::MAX_CONN_IN_FLIGHT) cap; a
/// few lanes multiply the funnel without meaningfully raising fd
/// count.
const BACKEND_LANES: usize = 4;

/// Dial timeout for backend connections (a dead shard on loopback
/// refuses instantly; a blackholed one must not stall the I/O thread).
const DIAL_TIMEOUT: Duration = Duration::from_secs(1);

/// Read timeout for the blocking probe / spec-replay connections.
const PROBE_TIMEOUT: Duration = Duration::from_secs(5);

/// Replicated-registration replay log cap — mirrors the per-shard
/// registry bound, so the log can never admit more than a shard would.
const MAX_REPLICATED_SPECS: usize = 1024;

/// Retry-after hint on re-routed (dead-shard) answers: the ring has
/// already been rebuilt, so the client's replay can land almost
/// immediately.
const REROUTE_RETRY_MS: u64 = 50;

/// Retry-after hint when the fleet has no live shard at all.
const NO_SHARD_RETRY_MS: u64 = 250;

// ---------------------------------------------------------------------------
// Affinity hashing
// ---------------------------------------------------------------------------

fn mode_code(mode: &ExecMode) -> u8 {
    match mode {
        ExecMode::BulkSync => 0,
        ExecMode::Serialized => 1,
        ExecMode::OutOfOrder => 2,
    }
}

/// The 64-bit cache-affinity key of one eval request: FNV-1a over its
/// semantic identity (spec ref, scenario, DSL, mode) with
/// length-prefixed fields, so adjacent fields cannot alias.  Priority
/// is deliberately excluded — the same mapper probed at a different
/// priority must still hit the shard that already evaluated it.
pub fn affinity_key(q: &WireEvalRequest) -> u64 {
    let mut h = Fnv1a::new();
    match &q.spec {
        SpecRef::Id(i) => {
            h.eat_field(b"id");
            h.eat_field(&i.to_le_bytes());
        }
        SpecRef::Name(n) => {
            h.eat_field(b"name");
            h.eat_field(n.as_bytes());
        }
    }
    h.eat_field(q.scenario.app.as_bytes());
    for (k, v) in &q.scenario.params {
        h.eat_field(k.as_bytes());
        h.eat_field(&v.to_le_bytes());
    }
    h.eat_field(q.dsl.as_bytes());
    h.eat_field(&[mode_code(&q.mode)]);
    h.finish()
}

/// A consistent-hash ring over shard addresses.  Each shard
/// contributes `vnodes` ring points at
/// `fnv1a([addr, vnode_index])` — a function of the shard alone, so a
/// membership change only re-owns the arcs adjacent to the points that
/// appeared or vanished (~1/N of the keyspace), never the whole ring.
pub struct HashRing {
    /// `(ring point, index into the build-time node slice)`, sorted.
    points: Vec<(u64, usize)>,
}

impl HashRing {
    /// Build the ring over `nodes` (order-insensitive: ties between
    /// colliding points break on the node string, so any permutation
    /// of the same membership routes identically).
    pub fn build(nodes: &[&str], vnodes: usize) -> HashRing {
        let mut points = Vec::with_capacity(nodes.len() * vnodes);
        for (idx, node) in nodes.iter().enumerate() {
            for v in 0..vnodes as u64 {
                points.push((
                    fnv1a(&[node.as_bytes(), &v.to_le_bytes()]),
                    idx,
                ));
            }
        }
        points.sort_by(|a, b| {
            a.0.cmp(&b.0).then_with(|| nodes[a.1].cmp(nodes[b.1]))
        });
        HashRing { points }
    }

    /// The node owning `key`: the first ring point at or after it,
    /// wrapping at the top.  `None` on an empty ring.
    pub fn route(&self, key: u64) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let i = self.points.partition_point(|p| p.0 < key);
        let i = if i == self.points.len() { 0 } else { i };
        Some(self.points[i].1)
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Fleet membership
// ---------------------------------------------------------------------------

/// Router-side state of one fleet member.
struct ShardState {
    /// The address string clients/opers name the shard by (also the
    /// ring-hash identity and the `addr` of its stats tail entry).
    name: String,
    addr: SocketAddr,
    /// [`SHARD_UP`] / [`SHARD_DRAINING`] / [`SHARD_DEAD`].
    state: AtomicU8,
    /// Eval items dispatched to this shard (router-side count).
    routed: AtomicU64,
    /// Backend frames awaiting an answer (drain waits on zero).
    inflight: AtomicU64,
}

impl ShardState {
    fn new(name: String, addr: SocketAddr) -> ShardState {
        ShardState {
            name,
            addr,
            state: AtomicU8::new(SHARD_UP),
            routed: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
        }
    }
}

/// Mark a shard unreachable (terminal until an explicit
/// [`EvalRouter::join_shard`]) and force every I/O thread to rebuild
/// its ring.
fn mark_dead(shard: &ShardState, shared: &RouterShared) {
    if shard.state.swap(SHARD_DEAD, Ordering::SeqCst) != SHARD_DEAD {
        shared.version.fetch_add(1, Ordering::SeqCst);
    }
}

/// One unit of a shard's in-flight accounting, owned by the backend
/// FIFO entry it accounts for — every resolution path (answered,
/// failed over, torn down) releases it exactly once.
struct InflightGuard(Arc<ShardState>);

impl InflightGuard {
    fn acquire(shard: &Arc<ShardState>) -> InflightGuard {
        shard.inflight.fetch_add(1, Ordering::SeqCst);
        InflightGuard(Arc::clone(shard))
    }
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

// ---------------------------------------------------------------------------
// Reply plumbing (single-threaded per I/O thread, hence Rc)
// ---------------------------------------------------------------------------

/// A front reply waiting on one backend response.
type RSlot = Rc<RefCell<Option<Response>>>;
/// One item of a scattered batch waiting on its sub-batch.
type ISlot = Rc<RefCell<Option<BatchItem>>>;

fn rslot() -> RSlot {
    Rc::new(RefCell::new(None))
}

fn islot() -> ISlot {
    Rc::new(RefCell::new(None))
}

/// Where a backend response lands.
enum Dest {
    Single(RSlot),
    /// The slots of one per-shard sub-batch, in sub-batch item order.
    SubBatch(Vec<ISlot>),
}

impl Dest {
    fn items(&self) -> u64 {
        match self {
            Dest::Single(_) => 1,
            Dest::SubBatch(slots) => slots.len() as u64,
        }
    }

    /// The shard died with this request in flight: answer *retryably*
    /// so the client's `RetryPolicy` replays onto the rebuilt ring.
    fn fail(&self, shard: &str) {
        let msg =
            format!("shard {shard} unreachable; request re-routed on retry");
        match self {
            Dest::Single(slot) => {
                *slot.borrow_mut() = Some(Response::Error {
                    kind: ErrorKind::Overloaded,
                    msg,
                    retry_after_ms: REROUTE_RETRY_MS,
                });
            }
            Dest::SubBatch(slots) => {
                for s in slots {
                    *s.borrow_mut() = Some(BatchItem::Error {
                        kind: ErrorKind::Overloaded,
                        msg: msg.clone(),
                        retry_after_ms: REROUTE_RETRY_MS,
                    });
                }
            }
        }
    }

    /// Route one backend response into its destination.
    fn fill(self, resp: Response) {
        match self {
            Dest::Single(slot) => *slot.borrow_mut() = Some(resp),
            Dest::SubBatch(slots) => match resp {
                Response::FeedbackBatch(items)
                    if items.len() == slots.len() =>
                {
                    for (slot, item) in slots.iter().zip(items) {
                        *slot.borrow_mut() = Some(item);
                    }
                }
                Response::Error { kind, msg, retry_after_ms } => {
                    // a top-level error answers every scattered item
                    // (retryable kinds stay retryable per item)
                    for s in &slots {
                        *s.borrow_mut() = Some(BatchItem::Error {
                            kind,
                            msg: msg.clone(),
                            retry_after_ms,
                        });
                    }
                }
                other => {
                    let msg = format!(
                        "shard answered a sub-batch with {}",
                        other.kind_name()
                    );
                    for s in &slots {
                        *s.borrow_mut() = Some(BatchItem::Error {
                            kind: ErrorKind::Internal,
                            msg: msg.clone(),
                            retry_after_ms: 0,
                        });
                    }
                }
            },
        }
    }
}

/// What a completed fan-out resolves into.
enum FanKind {
    /// All-shard registration; on unanimous ack the pair is appended
    /// to the replay log for future joiners.
    Register { name: String, spec: MachineSpec },
    /// Fleet stats aggregation.
    Stats,
    /// Fleet summary concatenation.
    Summary,
    /// Fleet flight-recorder dump: every shard's spans concatenated in
    /// membership order, the router's own appended last.
    TraceDump,
}

/// One queued front reply.
enum FReply {
    Now(Response),
    /// A forwarded request waiting on one shard.
    Slot(RSlot),
    /// A scattered batch, slots in original item order.
    Batch(Vec<ISlot>),
    /// A fan-out over the fleet, one slot per member.
    Fan { kind: FanKind, parts: Vec<(Arc<ShardState>, RSlot)> },
}

impl FReply {
    fn ready(&self) -> bool {
        match self {
            FReply::Now(_) => true,
            FReply::Slot(slot) => slot.borrow().is_some(),
            FReply::Batch(slots) => {
                slots.iter().all(|s| s.borrow().is_some())
            }
            FReply::Fan { parts, .. } => {
                parts.iter().all(|(_, s)| s.borrow().is_some())
            }
        }
    }

    /// Consume into the wire response (call only when [`FReply::ready`]).
    fn into_response(self, shared: &RouterShared) -> Response {
        match self {
            FReply::Now(r) => r,
            FReply::Slot(slot) => {
                slot.borrow_mut().take().expect("slot ready")
            }
            FReply::Batch(slots) => Response::FeedbackBatch(
                slots
                    .iter()
                    .map(|s| s.borrow_mut().take().expect("item ready"))
                    .collect(),
            ),
            FReply::Fan { kind, parts } => resolve_fan(kind, parts, shared),
        }
    }
}

fn state_label(state: u8) -> &'static str {
    match state {
        SHARD_UP => "up",
        SHARD_DRAINING => "draining",
        _ => "dead",
    }
}

fn resolve_fan(
    kind: FanKind,
    parts: Vec<(Arc<ShardState>, RSlot)>,
    shared: &RouterShared,
) -> Response {
    match kind {
        FanKind::Register { name, spec } => {
            let mut first: Option<Response> = None;
            for (_, slot) in &parts {
                let resp = slot.borrow_mut().take().expect("fan slot ready");
                match resp {
                    Response::Error { .. } => return resp,
                    r => {
                        if first.is_none() {
                            first = Some(r);
                        }
                    }
                }
            }
            // unanimous ack: remember the pair so joining shards can
            // be replayed up to date (re-registrations update in
            // place — the shards deduplicate by fingerprint anyway)
            let mut log = shared.spec_log.lock().unwrap();
            if let Some(entry) = log.iter_mut().find(|(n, _)| *n == name) {
                entry.1 = spec;
            } else if log.len() < MAX_REPLICATED_SPECS {
                log.push((name, spec));
            }
            first.unwrap_or(Response::Error {
                kind: ErrorKind::Internal,
                msg: "registration fan-out resolved with no parts".into(),
                retry_after_ms: 0,
            })
        }
        FanKind::Stats => {
            let contribs: Vec<ShardContribution> = parts
                .iter()
                .map(|(shard, slot)| {
                    let resp =
                        slot.borrow_mut().take().expect("fan slot ready");
                    ShardContribution {
                        addr: shard.name.clone(),
                        state: shard.state.load(Ordering::SeqCst),
                        routed: shard.routed.load(Ordering::SeqCst),
                        // an unreachable shard contributes zeroed
                        // counters — visible as a dead tail entry
                        snapshot: match resp {
                            Response::Stats(s) => s,
                            _ => StatsSnapshot::default(),
                        },
                    }
                })
                .collect();
            let mut snap = StatsSnapshot::aggregate_fleet(&contribs);
            // the router's own stages (route, upstream) join the
            // fleet-wide histogram set the shards contributed
            merge_stage_hists(
                &mut snap.stage_hists,
                &shared.obs.stages.snapshots(),
            );
            Response::Stats(snap)
        }
        FanKind::Summary => {
            let mut text = format!("fleet: {} shard(s)\n", parts.len());
            for (shard, slot) in &parts {
                let resp = slot.borrow_mut().take().expect("fan slot ready");
                let state = state_label(shard.state.load(Ordering::SeqCst));
                let routed = shard.routed.load(Ordering::SeqCst);
                text.push_str(&format!(
                    "-- shard {} [{state}] routed={routed} --\n",
                    shard.name
                ));
                match resp {
                    Response::Summary(s) => {
                        text.push_str(&s);
                        if !s.ends_with('\n') {
                            text.push('\n');
                        }
                    }
                    Response::Error { msg, .. } => {
                        text.push_str(&format!("(unreachable: {msg})\n"));
                    }
                    other => {
                        text.push_str(&format!(
                            "(unexpected {} reply)\n",
                            other.kind_name()
                        ));
                    }
                }
            }
            Response::Summary(text)
        }
        FanKind::TraceDump => {
            let mut spans = Vec::new();
            for (_, slot) in &parts {
                let resp = slot.borrow_mut().take().expect("fan slot ready");
                // dead / misbehaving shards simply contribute nothing
                if let Response::TraceDump(s) = resp {
                    spans.extend(s);
                }
            }
            spans.extend(shared.obs.recorder.dump());
            Response::TraceDump(spans)
        }
    }
}

// ---------------------------------------------------------------------------
// Backend links
// ---------------------------------------------------------------------------

/// One entry of a backend connection's reply FIFO.
struct Pending {
    dest: Dest,
    /// The nonzero trace ids riding this frame (empty when untraced) —
    /// a dead-shard bounce lands one `rerouted` span per id.
    ids: Vec<u64>,
    /// When the frame was queued on the backend link (the `upstream`
    /// histogram sample is queue→answer).
    sent: Instant,
    _guard: InflightGuard,
}

/// One nonblocking connection from an I/O thread to a shard.
struct Backend {
    shard: Arc<ShardState>,
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    wpos: usize,
    fifo: VecDeque<Pending>,
    /// Close once idle (clean EOF / shard-side reap with nothing
    /// pending) — the next dispatch simply redials.
    quiet_close: bool,
    /// Severed with work pending: fail over and mark the shard dead.
    dead: bool,
}

impl Backend {
    fn new(shard: Arc<ShardState>, stream: TcpStream) -> Backend {
        Backend {
            shard,
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            fifo: VecDeque::new(),
            quiet_close: false,
            dead: false,
        }
    }

    fn backlog(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    fn pump(&mut self, obs: &Telemetry) -> bool {
        let mut progressed = self.pump_write();
        progressed |= self.pump_read(obs);
        progressed
    }

    fn pump_write(&mut self) -> bool {
        let mut progressed = false;
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => {
                    self.wpos += n;
                    progressed = true;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos > (64 << 10) {
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
        progressed
    }

    fn pump_read(&mut self, obs: &Telemetry) -> bool {
        let mut progressed = false;
        let mut tmp = [0u8; 16 << 10];
        let mut budget = READ_BUDGET_PER_SCAN;
        while budget > 0 {
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    // EOF with work pending is a death (the shard never
                    // reaps a connection with evals in flight); idle
                    // EOF is a routine shard-side close
                    if self.fifo.is_empty() {
                        self.quiet_close = true;
                    } else {
                        self.dead = true;
                    }
                    break;
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&tmp[..n]);
                    progressed = true;
                    budget = budget.saturating_sub(n);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    if self.fifo.is_empty() {
                        self.quiet_close = true;
                    } else {
                        self.dead = true;
                    }
                    break;
                }
            }
        }
        while !self.dead {
            match proto::frame_step(&self.rbuf) {
                FrameStep::Incomplete => break,
                FrameStep::Frame { payload, consumed } => {
                    self.rbuf.drain(..consumed);
                    progressed = true;
                    match Response::decode(&payload) {
                        Ok(resp) => match self.fifo.pop_front() {
                            Some(p) => {
                                obs.stages
                                    .record_since(Stage::RouterUpstream, p.sent);
                                p.dest.fill(resp);
                            }
                            None => {
                                // unsolicited frame (e.g. an idle-reap
                                // notice): nothing is owed — close and
                                // let the next dispatch redial
                                self.quiet_close = true;
                                break;
                            }
                        },
                        Err(_) => {
                            // an undecodable *response* means the link
                            // lost protocol sync — fail over
                            self.dead = true;
                        }
                    }
                }
                FrameStep::Corrupt(_) => {
                    self.dead = true;
                }
            }
        }
        progressed
    }
}

// ---------------------------------------------------------------------------
// Per-thread routing context
// ---------------------------------------------------------------------------

struct ThreadCtx {
    shared: Arc<RouterShared>,
    backends: HashMap<(String, usize), Backend>,
    /// Cached membership (all states), refreshed on version change.
    members: Vec<Arc<ShardState>>,
    /// The routable (`up`) members the ring indexes into.
    ring_members: Vec<Arc<ShardState>>,
    ring: HashRing,
    seen: u64,
    /// Round-robin lane selector (see [`BACKEND_LANES`]).
    rr: usize,
}

impl ThreadCtx {
    fn new(shared: Arc<RouterShared>) -> ThreadCtx {
        ThreadCtx {
            shared,
            backends: HashMap::new(),
            members: Vec::new(),
            ring_members: Vec::new(),
            ring: HashRing::build(&[], RING_VNODES),
            seen: 0,
            rr: 0,
        }
    }

    /// Re-snapshot membership and rebuild the ring iff the fleet
    /// version moved (membership or state change).
    fn refresh(&mut self) {
        let v = self.shared.version.load(Ordering::SeqCst);
        if v == self.seen {
            return;
        }
        self.seen = v;
        self.members = self.shared.members.lock().unwrap().clone();
        self.ring_members = self
            .members
            .iter()
            .filter(|s| s.state.load(Ordering::SeqCst) == SHARD_UP)
            .cloned()
            .collect();
        let names: Vec<&str> =
            self.ring_members.iter().map(|s| s.name.as_str()).collect();
        self.ring = HashRing::build(&names, RING_VNODES);
    }

    fn route_eval(&self, q: &WireEvalRequest) -> Option<Arc<ShardState>> {
        let idx = self.ring.route(affinity_key(q))?;
        Some(Arc::clone(&self.ring_members[idx]))
    }

    /// Forward one encoded request to `shard`, registering `dest` for
    /// its answer.  A failed dial answers `dest` retryably and marks
    /// the shard dead (the caller's ring rebuilds before any retry).
    /// `ids` are the frame's nonzero trace ids (empty for untraced or
    /// non-eval frames); a failover lands one `rerouted` span per id.
    fn enqueue(
        &mut self,
        shard: &Arc<ShardState>,
        payload: &[u8],
        dest: Dest,
        ids: Vec<u64>,
    ) {
        self.rr = self.rr.wrapping_add(1);
        let key = (shard.name.clone(), self.rr % BACKEND_LANES);
        let b = match self.backends.entry(key) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(v) => match dial(shard) {
                Ok(stream) => {
                    v.insert(Backend::new(Arc::clone(shard), stream))
                }
                Err(_) => {
                    mark_dead(shard, &self.shared);
                    self.shared
                        .rerouted
                        .fetch_add(dest.items(), Ordering::SeqCst);
                    note_rerouted(&self.shared.obs, &ids, Instant::now());
                    dest.fail(&shard.name);
                    return;
                }
            },
        };
        if proto::write_frame(&mut b.wbuf, payload).is_err() {
            // a re-encoded request cannot exceed the frame cap its
            // original fit under; stay safe anyway
            note_rerouted(&self.shared.obs, &ids, Instant::now());
            dest.fail(&shard.name);
            return;
        }
        b.fifo.push_back(Pending {
            dest,
            ids,
            sent: Instant::now(),
            _guard: InflightGuard::acquire(shard),
        });
    }

    /// Drive every backend link; severed links fail their pending work
    /// over to the retry path.
    fn pump_backends(&mut self) -> bool {
        let shared = Arc::clone(&self.shared);
        let mut progressed = false;
        self.backends.retain(|_, b| {
            progressed |= b.pump(&shared.obs);
            if b.dead {
                fail_backend(b, &shared);
                let _ = b.stream.shutdown(Shutdown::Both);
                progressed = true;
                return false;
            }
            if b.quiet_close && b.fifo.is_empty() && b.backlog() == 0 {
                let _ = b.stream.shutdown(Shutdown::Both);
                progressed = true;
                return false;
            }
            true
        });
        progressed
    }

    fn live_members(&self) -> Vec<Arc<ShardState>> {
        self.members
            .iter()
            .filter(|s| s.state.load(Ordering::SeqCst) != SHARD_DEAD)
            .cloned()
            .collect()
    }

    fn dispatch(&mut self, req: Request) -> FReply {
        match req {
            Request::Ping => FReply::Now(Response::Pong),
            Request::Eval(q) => {
                let Some(shard) = self.route_eval(&q) else {
                    return FReply::Now(no_live_shards());
                };
                shard.routed.fetch_add(1, Ordering::SeqCst);
                let slot = rslot();
                let ids = if q.trace_id != 0 { vec![q.trace_id] } else { vec![] };
                let payload = Request::Eval(q).encode();
                self.enqueue(
                    &shard,
                    &payload,
                    Dest::Single(Rc::clone(&slot)),
                    ids,
                );
                FReply::Slot(slot)
            }
            Request::EvalBatch(items) => self.dispatch_batch(items),
            Request::RegisterSpec { name, spec } => {
                let targets = self.live_members();
                if targets.is_empty() {
                    return FReply::Now(no_live_shards());
                }
                let payload = Request::RegisterSpec {
                    name: name.clone(),
                    spec: spec.clone(),
                }
                .encode();
                let mut parts = Vec::with_capacity(targets.len());
                for shard in targets {
                    let slot = rslot();
                    self.enqueue(
                        &shard,
                        &payload,
                        Dest::Single(Rc::clone(&slot)),
                        Vec::new(),
                    );
                    parts.push((shard, slot));
                }
                FReply::Fan { kind: FanKind::Register { name, spec }, parts }
            }
            Request::GetSpec { name } => {
                let Some(shard) = self.live_members().into_iter().next()
                else {
                    return FReply::Now(no_live_shards());
                };
                let slot = rslot();
                let payload = Request::GetSpec { name }.encode();
                self.enqueue(
                    &shard,
                    &payload,
                    Dest::Single(Rc::clone(&slot)),
                    Vec::new(),
                );
                FReply::Slot(slot)
            }
            Request::Stats => self.dispatch_fan(FanKind::Stats),
            Request::Summary => self.dispatch_fan(FanKind::Summary),
            Request::TraceDump => self.dispatch_fan(FanKind::TraceDump),
        }
    }

    /// Scatter a batch into per-shard sub-batches (original per-shard
    /// item order preserved) and gather one equal-length reply.
    fn dispatch_batch(&mut self, items: Vec<WireEvalRequest>) -> FReply {
        let mut slots: Vec<ISlot> = Vec::with_capacity(items.len());
        let mut groups: Vec<(
            Arc<ShardState>,
            Vec<WireEvalRequest>,
            Vec<ISlot>,
        )> = Vec::new();
        for q in items {
            let slot = islot();
            slots.push(Rc::clone(&slot));
            match self.route_eval(&q) {
                Some(shard) => {
                    match groups
                        .iter_mut()
                        .find(|g| Arc::ptr_eq(&g.0, &shard))
                    {
                        Some(g) => {
                            g.1.push(q);
                            g.2.push(slot);
                        }
                        None => groups.push((shard, vec![q], vec![slot])),
                    }
                }
                None => {
                    *slot.borrow_mut() = Some(BatchItem::Error {
                        kind: ErrorKind::Overloaded,
                        msg: "no live shards in the fleet".into(),
                        retry_after_ms: NO_SHARD_RETRY_MS,
                    });
                }
            }
        }
        for (shard, sub, sub_slots) in groups {
            shard.routed.fetch_add(sub.len() as u64, Ordering::SeqCst);
            let ids: Vec<u64> = sub
                .iter()
                .map(|q| q.trace_id)
                .filter(|&t| t != 0)
                .collect();
            let payload = Request::EvalBatch(sub).encode();
            self.enqueue(&shard, &payload, Dest::SubBatch(sub_slots), ids);
        }
        FReply::Batch(slots)
    }

    /// Fan a stats/summary probe over *every* member; dead members get
    /// a pre-failed slot so the aggregate still lists them.
    fn dispatch_fan(&mut self, kind: FanKind) -> FReply {
        if self.members.is_empty() {
            return FReply::Now(match kind {
                FanKind::Stats => {
                    let mut snap = StatsSnapshot::aggregate_fleet(&[]);
                    merge_stage_hists(
                        &mut snap.stage_hists,
                        &self.shared.obs.stages.snapshots(),
                    );
                    Response::Stats(snap)
                }
                FanKind::TraceDump => {
                    Response::TraceDump(self.shared.obs.recorder.dump())
                }
                _ => Response::Summary("fleet: 0 shard(s)\n".to_string()),
            });
        }
        let payload = match kind {
            FanKind::Stats => Request::Stats.encode(),
            FanKind::TraceDump => Request::TraceDump.encode(),
            _ => Request::Summary.encode(),
        };
        let members = self.members.clone();
        let mut parts = Vec::with_capacity(members.len());
        for shard in members {
            let slot = rslot();
            if shard.state.load(Ordering::SeqCst) == SHARD_DEAD {
                *slot.borrow_mut() = Some(Response::Error {
                    kind: ErrorKind::Overloaded,
                    msg: format!("shard {} is dead", shard.name),
                    retry_after_ms: 0,
                });
            } else {
                self.enqueue(
                    &shard,
                    &payload,
                    Dest::Single(Rc::clone(&slot)),
                    Vec::new(),
                );
            }
            parts.push((shard, slot));
        }
        FReply::Fan { kind, parts }
    }
}

fn no_live_shards() -> Response {
    Response::Error {
        kind: ErrorKind::Overloaded,
        msg: "no live shards in the fleet".into(),
        retry_after_ms: NO_SHARD_RETRY_MS,
    }
}

fn fail_backend(b: &mut Backend, shared: &RouterShared) {
    if b.fifo.is_empty() {
        return;
    }
    mark_dead(&b.shard, shared);
    let mut items = 0u64;
    while let Some(p) = b.fifo.pop_front() {
        items += p.dest.items();
        note_rerouted(&shared.obs, &p.ids, p.sent);
        p.dest.fail(&b.shard.name);
    }
    shared.rerouted.fetch_add(items, Ordering::SeqCst);
}

/// Land one `rerouted` span per traced id that was just failed over —
/// the forensic trail of a dead-shard bounce (the client's retry will
/// open a fresh span on the surviving shard).
fn note_rerouted(obs: &Telemetry, ids: &[u64], sent: Instant) {
    for &id in ids {
        let mut span = SpanBuilder::begin_at(id, sent);
        let waited = sent.elapsed().as_nanos() as u64;
        span.stage(Stage::RouterUpstream, sent, waited);
        span.outcome(SPAN_REROUTED);
        obs.recorder.push(span.finish());
    }
}

fn dial(shard: &ShardState) -> io::Result<TcpStream> {
    let stream = TcpStream::connect_timeout(&shard.addr, DIAL_TIMEOUT)?;
    let _ = stream.set_nodelay(true);
    stream.set_nonblocking(true)?;
    Ok(stream)
}

// ---------------------------------------------------------------------------
// Front connections (mirrors the server's slab pump)
// ---------------------------------------------------------------------------

struct FrontConn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    wpos: usize,
    fifo: VecDeque<FReply>,
    last_read: Instant,
    last_write_progress: Instant,
    read_closed: bool,
    dead: bool,
}

impl FrontConn {
    fn adopt(stream: TcpStream) -> FrontConn {
        let now = Instant::now();
        FrontConn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            fifo: VecDeque::new(),
            last_read: now,
            last_write_progress: now,
            read_closed: false,
            dead: false,
        }
    }

    fn backlog(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    fn finished(&self) -> bool {
        self.dead
            || (self.read_closed
                && self.fifo.is_empty()
                && self.backlog() == 0)
    }

    /// Read, frame, and dispatch buffered requests (the first half of
    /// a scan; backend pumping and reply egress run after).
    fn pump_ingress(&mut self, ctx: &mut ThreadCtx) -> bool {
        if self.read_closed || self.backlog() >= MAX_WRITE_BACKLOG {
            return false;
        }
        let mut progressed = false;
        let mut tmp = [0u8; 16 << 10];
        let mut budget = READ_BUDGET_PER_SCAN;
        while budget > 0 {
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    self.read_closed = true;
                    break;
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&tmp[..n]);
                    self.last_read = Instant::now();
                    progressed = true;
                    budget = budget.saturating_sub(n);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    return true;
                }
            }
        }
        while self.fifo.len() < MAX_PENDING_REPLIES {
            match proto::frame_step(&self.rbuf) {
                FrameStep::Incomplete => break,
                FrameStep::Frame { payload, consumed } => {
                    self.rbuf.drain(..consumed);
                    let reply = match Request::decode(&payload) {
                        Ok(req) => {
                            let t_route = Instant::now();
                            let r = ctx.dispatch(req);
                            ctx.shared
                                .obs
                                .stages
                                .record_since(Stage::RouterRoute, t_route);
                            r
                        }
                        Err(e) => FReply::Now(Response::Error {
                            kind: e.wire_kind(),
                            msg: e.to_string(),
                            retry_after_ms: 0,
                        }),
                    };
                    self.fifo.push_back(reply);
                    progressed = true;
                }
                FrameStep::Corrupt(msg) => {
                    self.fifo.push_back(FReply::Now(Response::Error {
                        kind: ErrorKind::Frame,
                        msg,
                        retry_after_ms: 0,
                    }));
                    self.rbuf.clear();
                    self.read_closed = true;
                    progressed = true;
                    break;
                }
            }
        }
        progressed
    }

    /// Encode ready replies in request order and flush (the second
    /// half of a scan).
    fn pump_egress(
        &mut self,
        shared: &RouterShared,
        deadline: Option<Duration>,
    ) -> bool {
        let mut progressed = false;
        while self.fifo.front().is_some_and(FReply::ready) {
            let reply = self.fifo.pop_front().expect("checked front");
            let resp = reply.into_response(shared);
            if proto::write_frame(&mut self.wbuf, &resp.encode()).is_err() {
                self.dead = true;
                return true;
            }
            progressed = true;
        }
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => {
                    self.wpos += n;
                    self.last_write_progress = Instant::now();
                    progressed = true;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos > (64 << 10) {
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
        self.check_deadline(shared, deadline);
        progressed
    }

    /// Same reaping rules as the server: idle fronts get a polite
    /// retryable `Deadline` answer; stalled writers are closed hard;
    /// fronts with replies pending are never reaped.
    fn check_deadline(
        &mut self,
        shared: &RouterShared,
        deadline: Option<Duration>,
    ) {
        let Some(d) = deadline else { return };
        if self.dead {
            return;
        }
        if self.backlog() > 0 {
            if self.last_write_progress.elapsed() > d {
                shared.reaped.fetch_add(1, Ordering::SeqCst);
                self.dead = true;
            }
            return;
        }
        if self.read_closed || !self.fifo.is_empty() {
            return;
        }
        if self.last_read.elapsed() > d {
            shared.reaped.fetch_add(1, Ordering::SeqCst);
            let secs = d.as_secs();
            self.fifo.push_back(FReply::Now(Response::Error {
                kind: ErrorKind::Deadline,
                msg: format!(
                    "connection idle past the router's {secs}s read \
                     deadline; reconnect and resume"
                ),
                retry_after_ms: 0,
            }));
            self.read_closed = true;
        }
    }
}

// ---------------------------------------------------------------------------
// The I/O pool
// ---------------------------------------------------------------------------

const STATE_RUNNING: u8 = 0;
const STATE_DRAIN: u8 = 1;
const STATE_KILL: u8 = 2;

struct RouterShared {
    active: AtomicUsize,
    state: AtomicU8,
    inboxes: Vec<Mutex<Vec<TcpStream>>>,
    /// The fleet, any state; guarded so join/leave and the snapshots
    /// the I/O threads take stay consistent.
    members: Mutex<Vec<Arc<ShardState>>>,
    /// Bumped on every membership/state change; threads rebuild their
    /// ring when it moves.
    version: AtomicU64,
    /// Unanimously-acked registrations, replayed into joining shards.
    spec_log: Mutex<Vec<(String, MachineSpec)>>,
    /// In-flight requests failed over off dead shards (each answered
    /// retryably, replayed by the client onto the rebuilt ring).
    rerouted: AtomicU64,
    /// Front connections reaped at the idle deadline.
    reaped: AtomicU64,
    /// Front connections refused at the connection cap.
    refused: AtomicU64,
    /// The router's own telemetry: `route` / `upstream` stage
    /// histograms and the reroute flight recorder (distinct from the
    /// shards' — the fleet `Stats` / `TraceDump` answers combine both).
    obs: Telemetry,
}

fn io_loop(idx: usize, shared: Arc<RouterShared>, deadline: Option<Duration>) {
    let mut ctx = ThreadCtx::new(Arc::clone(&shared));
    let mut slab: Vec<Option<FrontConn>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut idle_spins: u32 = 0;
    loop {
        let state = shared.state.load(Ordering::SeqCst);
        ctx.refresh();
        let incoming: Vec<TcpStream> = {
            let mut q = shared.inboxes[idx].lock().unwrap();
            std::mem::take(&mut *q)
        };
        let mut progressed = !incoming.is_empty();
        for stream in incoming {
            if state == STATE_KILL {
                let _ = stream.shutdown(Shutdown::Both);
                shared.active.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
            let conn = FrontConn::adopt(stream);
            match free.pop() {
                Some(i) => slab[i] = Some(conn),
                None => slab.push(Some(conn)),
            }
        }
        for slot in 0..slab.len() {
            let Some(conn) = slab[slot].as_mut() else { continue };
            match state {
                STATE_KILL => conn.dead = true,
                STATE_DRAIN => conn.read_closed = true,
                _ => {}
            }
            if !conn.dead {
                progressed |= conn.pump_ingress(&mut ctx);
            }
        }
        progressed |= ctx.pump_backends();
        for slot in 0..slab.len() {
            let finished = {
                let Some(conn) = slab[slot].as_mut() else { continue };
                if !conn.dead {
                    progressed |= conn.pump_egress(&shared, deadline);
                }
                conn.finished()
            };
            if finished {
                if let Some(conn) = slab[slot].take() {
                    let _ = conn.stream.shutdown(Shutdown::Both);
                }
                free.push(slot);
                shared.active.fetch_sub(1, Ordering::SeqCst);
                progressed = true;
            }
        }
        if state != STATE_RUNNING
            && slab.iter().all(Option::is_none)
            && shared.inboxes[idx].lock().unwrap().is_empty()
        {
            break;
        }
        if progressed {
            idle_spins = 0;
        } else {
            idle_spins = idle_spins.saturating_add(1);
            if idle_spins <= 3 {
                thread::yield_now();
            } else {
                let us = (50 * idle_spins as u64).min(500);
                thread::sleep(Duration::from_micros(us));
            }
        }
    }
    // graceful exits already resolved every pending entry (front
    // connections only finish once their replies filled); sever
    // whatever links remain
    for (_, b) in ctx.backends.drain() {
        let _ = b.stream.shutdown(Shutdown::Both);
    }
}

// ---------------------------------------------------------------------------
// The router front
// ---------------------------------------------------------------------------

fn invalid_data(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

fn resolve(addr: &str) -> io::Result<SocketAddr> {
    addr.to_socket_addrs()?.next().ok_or_else(|| {
        io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("shard address '{addr}' resolves to nothing"),
        )
    })
}

/// A blocking liveness probe: dial, ping, expect pong.
fn probe(addr: &SocketAddr) -> io::Result<()> {
    let mut stream = TcpStream::connect_timeout(addr, DIAL_TIMEOUT)?;
    stream.set_read_timeout(Some(PROBE_TIMEOUT))?;
    let _ = stream.set_nodelay(true);
    proto::write_frame(&mut stream, &Request::Ping.encode())?;
    match proto::read_frame(&mut stream)? {
        Some(payload) => match Response::decode(&payload) {
            Ok(Response::Pong) => Ok(()),
            Ok(other) => Err(invalid_data(format!(
                "expected Pong, shard answered {}",
                other.kind_name()
            ))),
            Err(e) => Err(invalid_data(e.to_string())),
        },
        None => Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            "shard closed the probe connection before answering",
        )),
    }
}

/// The sharded-fleet front (see module docs).  Binds like an
/// [`EvalServer`](super::EvalServer) — same wire protocol, same knobs
/// — but forwards evaluation work across its shards.
pub struct EvalRouter {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
    io: Vec<thread::JoinHandle<()>>,
    shared: Arc<RouterShared>,
}

impl EvalRouter {
    /// Bind `addr` fronting `shards` (backend `EvalServer` addresses)
    /// with env-derived [`ServerConfig`] defaults.  Every initial
    /// shard must pass a ping probe — a misconfigured fleet fails at
    /// bind, not on the first routed eval.
    pub fn bind(addr: &str, shards: &[String]) -> io::Result<EvalRouter> {
        EvalRouter::bind_with(addr, shards, ServerConfig::default())
    }

    /// [`EvalRouter::bind`] with explicit knobs.
    pub fn bind_with(
        addr: &str,
        shards: &[String],
        config: ServerConfig,
    ) -> io::Result<EvalRouter> {
        let mut members: Vec<Arc<ShardState>> =
            Vec::with_capacity(shards.len());
        for s in shards {
            if members.iter().any(|m| m.name == *s) {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("duplicate shard address {s}"),
                ));
            }
            let sa = resolve(s)?;
            probe(&sa).map_err(|e| {
                io::Error::new(
                    e.kind(),
                    format!("shard {s} failed its ping probe: {e}"),
                )
            })?;
            members.push(Arc::new(ShardState::new(s.clone(), sa)));
        }
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let io_threads = config.io_threads.max(1);
        let max_connections = config.max_connections.max(1);
        let deadline = config.conn_deadline;
        let shared = Arc::new(RouterShared {
            active: AtomicUsize::new(0),
            state: AtomicU8::new(STATE_RUNNING),
            inboxes: (0..io_threads).map(|_| Mutex::new(Vec::new())).collect(),
            members: Mutex::new(members),
            version: AtomicU64::new(1),
            spec_log: Mutex::new(Vec::new()),
            rerouted: AtomicU64::new(0),
            reaped: AtomicU64::new(0),
            refused: AtomicU64::new(0),
            obs: Telemetry::from_env(),
        });
        let mut io = Vec::with_capacity(io_threads);
        for i in 0..io_threads {
            let shared = Arc::clone(&shared);
            io.push(
                thread::Builder::new()
                    .name(format!("evalrtr-io-{i}"))
                    .spawn(move || io_loop(i, shared, deadline))?,
            );
        }
        let accept_shared = Arc::clone(&shared);
        let accept = thread::Builder::new()
            .name("evalrtr-accept".into())
            .spawn(move || {
                let mut next = 0usize;
                for conn in listener.incoming() {
                    if stop_flag.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(mut stream) => {
                            let prev = accept_shared
                                .active
                                .fetch_add(1, Ordering::SeqCst);
                            if prev >= max_connections {
                                accept_shared
                                    .active
                                    .fetch_sub(1, Ordering::SeqCst);
                                accept_shared
                                    .refused
                                    .fetch_add(1, Ordering::SeqCst);
                                let resp = Response::Error {
                                    kind: ErrorKind::Overloaded,
                                    msg: format!(
                                        "router at connection capacity \
                                         ({max_connections})"
                                    ),
                                    retry_after_ms: 250,
                                };
                                let _ = proto::write_frame(
                                    &mut stream,
                                    &resp.encode(),
                                );
                                let _ = stream.shutdown(Shutdown::Both);
                                continue;
                            }
                            let _ = stream.set_nodelay(true);
                            if stream.set_nonblocking(true).is_err() {
                                accept_shared
                                    .active
                                    .fetch_sub(1, Ordering::SeqCst);
                                continue;
                            }
                            let inbox = next % accept_shared.inboxes.len();
                            next = next.wrapping_add(1);
                            accept_shared.inboxes[inbox]
                                .lock()
                                .unwrap()
                                .push(stream);
                        }
                        Err(_) => {
                            thread::sleep(Duration::from_millis(50));
                            continue;
                        }
                    }
                }
            })?;
        Ok(EvalRouter { addr: local, stop, accept: Some(accept), io, shared })
    }

    /// The bound front address (resolves ephemeral `":0"` binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// In-flight requests failed over off dead shards so far (each was
    /// answered retryably and replayed by its client).
    pub fn rerouted(&self) -> u64 {
        self.shared.rerouted.load(Ordering::SeqCst)
    }

    /// Front connections refused at the connection cap.
    pub fn refused(&self) -> u64 {
        self.shared.refused.load(Ordering::SeqCst)
    }

    /// `(addr, state)` of every member, in membership order (states
    /// are the `SHARD_*` constants).
    pub fn shard_states(&self) -> Vec<(String, u8)> {
        self.shared
            .members
            .lock()
            .unwrap()
            .iter()
            .map(|m| (m.name.clone(), m.state.load(Ordering::SeqCst)))
            .collect()
    }

    /// Add a shard at runtime: probe it, replay the replicated spec
    /// log into it, then admit it to the ring (a dead member with the
    /// same address is replaced).  Until this returns the shard takes
    /// no traffic, so a half-replayed joiner can never serve.
    pub fn join_shard(&self, addr: &str) -> io::Result<()> {
        let sa = resolve(addr)?;
        {
            let members = self.shared.members.lock().unwrap();
            if members.iter().any(|m| {
                m.name == addr
                    && m.state.load(Ordering::SeqCst) != SHARD_DEAD
            }) {
                return Err(io::Error::new(
                    io::ErrorKind::AlreadyExists,
                    format!("shard {addr} is already a fleet member"),
                ));
            }
        }
        probe(&sa)?;
        let log = self.shared.spec_log.lock().unwrap().clone();
        if !log.is_empty() {
            let mut stream = TcpStream::connect_timeout(&sa, DIAL_TIMEOUT)?;
            stream.set_read_timeout(Some(PROBE_TIMEOUT))?;
            let _ = stream.set_nodelay(true);
            for (name, spec) in log {
                let req = Request::RegisterSpec { name: name.clone(), spec };
                proto::write_frame(&mut stream, &req.encode())?;
                match proto::read_frame(&mut stream)? {
                    Some(p) => match Response::decode(&p) {
                        Ok(Response::SpecInfo { .. }) => {}
                        Ok(Response::Error { msg, .. }) => {
                            return Err(invalid_data(format!(
                                "shard {addr} refused replayed spec \
                                 '{name}': {msg}"
                            )));
                        }
                        Ok(other) => {
                            return Err(invalid_data(format!(
                                "spec replay to {addr} answered {}",
                                other.kind_name()
                            )));
                        }
                        Err(e) => return Err(invalid_data(e.to_string())),
                    },
                    None => {
                        return Err(io::Error::new(
                            io::ErrorKind::UnexpectedEof,
                            format!(
                                "shard {addr} closed during spec replay"
                            ),
                        ));
                    }
                }
            }
        }
        let mut members = self.shared.members.lock().unwrap();
        members.retain(|m| m.name != addr);
        members.push(Arc::new(ShardState::new(addr.to_string(), sa)));
        drop(members);
        self.shared.version.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }

    /// Gracefully remove a shard: stop routing new work to it
    /// (`draining`), wait for its in-flight requests to settle, then
    /// detach it.  Times out leaving the shard draining (retryable);
    /// its settled work was still answered.
    pub fn leave_shard(
        &self,
        addr: &str,
        timeout: Duration,
    ) -> io::Result<()> {
        let shard = {
            let members = self.shared.members.lock().unwrap();
            members
                .iter()
                .find(|m| {
                    m.name == addr
                        && m.state.load(Ordering::SeqCst) != SHARD_DEAD
                })
                .cloned()
        };
        let Some(shard) = shard else {
            return Err(io::Error::new(
                io::ErrorKind::NotFound,
                format!("shard {addr} is not a live fleet member"),
            ));
        };
        if shard
            .state
            .compare_exchange(
                SHARD_UP,
                SHARD_DRAINING,
                Ordering::SeqCst,
                Ordering::SeqCst,
            )
            .is_ok()
        {
            self.shared.version.fetch_add(1, Ordering::SeqCst);
        }
        let start = Instant::now();
        while shard.inflight.load(Ordering::SeqCst) > 0 {
            if start.elapsed() > timeout {
                return Err(io::Error::new(
                    io::ErrorKind::TimedOut,
                    format!(
                        "shard {addr} still has in-flight work after \
                         {timeout:?}; left draining"
                    ),
                ));
            }
            thread::sleep(Duration::from_millis(1));
        }
        let mut members = self.shared.members.lock().unwrap();
        members.retain(|m| !Arc::ptr_eq(m, &shard));
        drop(members);
        self.shared.version.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }

    /// Block until the I/O pool exits (the route-forever CLI path).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.io.drain(..) {
            let _ = h.join();
        }
    }

    /// Graceful drain: stop accepting, answer everything in flight
    /// (backend links included), flush, join.
    pub fn shutdown(mut self) {
        self.drain();
    }

    /// Abrupt stop: sever every front and backend connection.
    pub fn kill(mut self) {
        self.stop_accepting();
        self.shared.state.store(STATE_KILL, Ordering::SeqCst);
        for h in self.io.drain(..) {
            let _ = h.join();
        }
    }

    fn drain(&mut self) {
        self.stop_accepting();
        let _ = self.shared.state.compare_exchange(
            STATE_RUNNING,
            STATE_DRAIN,
            Ordering::SeqCst,
            Ordering::SeqCst,
        );
        for h in self.io.drain(..) {
            let _ = h.join();
        }
    }

    fn stop_accepting(&mut self) {
        if let Some(h) = self.accept.take() {
            self.stop.store(true, Ordering::SeqCst);
            let mut target = self.addr;
            if target.ip().is_unspecified() {
                let loopback = match target.ip() {
                    IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                    IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
                };
                target.set_ip(loopback);
            }
            let _ = TcpStream::connect(target);
            let _ = h.join();
        }
    }
}

impl Drop for EvalRouter {
    fn drop(&mut self) {
        self.drain();
    }
}

#[cfg(test)]
mod tests {
    use super::super::proto::Scenario;
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ring_moves_only_the_removed_shards_keys() {
        let nodes3 = ["127.0.0.1:7001", "127.0.0.1:7002", "127.0.0.1:7003"];
        let nodes2 = [nodes3[0], nodes3[1]];
        let r3 = HashRing::build(&nodes3, RING_VNODES);
        let r2 = HashRing::build(&nodes2, RING_VNODES);
        assert_eq!(r3.len(), 3 * RING_VNODES);
        assert!(!r3.is_empty());
        let mut rng = Rng::new(0x51A2);
        let (mut moved, mut total) = (0u32, 0u32);
        for _ in 0..10_000 {
            let key = rng.next_u64();
            let from = nodes3[r3.route(key).unwrap()];
            let to = nodes2[r2.route(key).unwrap()];
            total += 1;
            if from == nodes3[2] {
                moved += 1;
            } else {
                assert_eq!(
                    from, to,
                    "a key not on the removed shard must not move"
                );
            }
        }
        // ~1/3 of the keyspace belonged to the removed shard; losing
        // it must never reshuffle the survivors
        assert!(moved > 0, "the removed shard owned nothing");
        assert!(
            (moved as f64) < 0.5 * total as f64,
            "{moved}/{total} keys moved — that is a reshuffle"
        );

        // membership order cannot matter
        let shuffled = ["127.0.0.1:7003", "127.0.0.1:7001", "127.0.0.1:7002"];
        let rs = HashRing::build(&shuffled, RING_VNODES);
        for _ in 0..1_000 {
            let key = rng.next_u64();
            assert_eq!(
                nodes3[r3.route(key).unwrap()],
                shuffled[rs.route(key).unwrap()],
            );
        }

        assert_eq!(HashRing::build(&[], RING_VNODES).route(42), None);
    }

    #[test]
    fn affinity_key_binds_semantics_not_priority() {
        let base = WireEvalRequest {
            spec: SpecRef::Id(0),
            scenario: Scenario::named("circuit"),
            dsl: "task * region * : place = ANY;".into(),
            mode: ExecMode::Serialized,
            priority: 128,
            trace_id: 0,
        };
        assert_eq!(affinity_key(&base), affinity_key(&base.clone()));

        // the same mapper at a different priority must land on the
        // same warm shard
        let mut hot = base.clone();
        hot.priority = 255;
        assert_eq!(affinity_key(&base), affinity_key(&hot));

        // tracing is inert: a stamped id must not change routing (a
        // traced re-submission has to reach the same warm shard)
        let mut traced = base.clone();
        traced.trace_id = 0xDEAD_BEEF;
        assert_eq!(affinity_key(&base), affinity_key(&traced));

        let mut dsl = base.clone();
        dsl.dsl.push(' ');
        assert_ne!(affinity_key(&base), affinity_key(&dsl));

        let mut mode = base.clone();
        mode.mode = ExecMode::OutOfOrder;
        assert_ne!(affinity_key(&base), affinity_key(&mode));

        let mut scen = base.clone();
        scen.scenario.params.push(("pieces".into(), 4));
        assert_ne!(affinity_key(&base), affinity_key(&scen));

        // spec refs are tagged: Id(0) and Name("0") cannot alias
        let mut named = base.clone();
        named.spec = SpecRef::Name("0".into());
        assert_ne!(affinity_key(&base), affinity_key(&named));
    }
}
