//! The remote eval client: the cross-process face of
//! [`EvalService`](crate::coordinator::EvalService), with the same
//! `evaluate` / `submit`-plus-ticket shape — so campaigns drive a
//! remote backend exactly like an in-process one (the
//! `Coordinator`-compatible adapter is
//! [`Coordinator::remote`](crate::coordinator::Coordinator::remote)).
//!
//! One socket carries any number of in-flight requests: senders
//! serialize frames under the writer lock (pushing their reply slot in
//! the same critical section, so slot order equals frame order) and a
//! dedicated reader thread matches responses FIFO.  A dead connection
//! resolves every outstanding and future ticket with a classified
//! `Remote:` execution error instead of hanging or panicking.

use std::collections::VecDeque;
use std::io;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use crate::coordinator::StatsSnapshot;
use crate::feedback::SystemFeedback;
use crate::machine::MachineSpec;
use crate::sim::ExecMode;

use super::proto::{
    self, Request, Response, Scenario, SpecRef, WireEvalRequest,
};

/// One awaited response slot (FIFO-matched by the reader thread).
#[derive(Default)]
struct ReplySlot {
    done: Mutex<Option<Result<Response, String>>>,
    cv: Condvar,
}

impl ReplySlot {
    /// First fill wins (a send-side failure and the reader's drain can
    /// race; both write errors, so either order is correct).
    fn fill(&self, r: Result<Response, String>) {
        let mut g = self.done.lock().unwrap();
        if g.is_none() {
            *g = Some(r);
            self.cv.notify_all();
        }
    }

    fn wait(&self) -> Result<Response, String> {
        let mut g = self.done.lock().unwrap();
        loop {
            if let Some(r) = g.as_ref() {
                return r.clone();
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    fn poll(&self) -> Option<Result<Response, String>> {
        self.done.lock().unwrap().clone()
    }
}

struct ClientInner {
    /// Write half; also the lock that orders `pending` pushes.
    writer: Mutex<TcpStream>,
    /// Outstanding slots in frame order (reader pops front per frame).
    pending: Mutex<VecDeque<Arc<ReplySlot>>>,
    /// Set once the connection is unusable; new sends fail fast.
    dead: AtomicBool,
}

impl ClientInner {
    fn fail_all_pending(&self, msg: &str) {
        let drained: Vec<Arc<ReplySlot>> =
            self.pending.lock().unwrap().drain(..).collect();
        for slot in drained {
            slot.fill(Err(msg.to_string()));
        }
    }
}

/// Completion handle of one remote submission — the wire twin of
/// [`EvalTicket`](crate::coordinator::EvalTicket).
pub struct RemoteTicket {
    slot: Arc<ReplySlot>,
}

impl RemoteTicket {
    /// Block until the server answers (or the connection dies); every
    /// non-feedback outcome is classified as an execution error, so
    /// campaign code never sees a second error channel.
    pub fn wait(&self) -> SystemFeedback {
        feedback_of(self.slot.wait())
    }

    /// Non-blocking check; `Some` once the response arrived.
    pub fn poll(&self) -> Option<SystemFeedback> {
        self.slot.poll().map(feedback_of)
    }

    pub fn is_done(&self) -> bool {
        self.slot.done.lock().unwrap().is_some()
    }
}

fn feedback_of(r: Result<Response, String>) -> SystemFeedback {
    match r {
        Ok(Response::Feedback(fb)) => fb,
        Ok(Response::Error { kind, msg }) => {
            SystemFeedback::ExecutionError(format!("Remote {kind} error: {msg}"))
        }
        Ok(other) => SystemFeedback::ExecutionError(format!(
            "Remote protocol error: expected feedback, got {}",
            other.kind_name()
        )),
        Err(e) => SystemFeedback::ExecutionError(format!("Remote transport error: {e}")),
    }
}

/// A connection to a remote [`EvalServer`](super::EvalServer) (see
/// module docs).
pub struct RemoteEvalClient {
    inner: Arc<ClientInner>,
    reader: Mutex<Option<thread::JoinHandle<()>>>,
}

impl RemoteEvalClient {
    /// Connect and start the response-matching reader thread.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<RemoteEvalClient> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let read_half = stream.try_clone()?;
        let inner = Arc::new(ClientInner {
            writer: Mutex::new(stream),
            pending: Mutex::new(VecDeque::new()),
            dead: AtomicBool::new(false),
        });
        let rx_inner = Arc::clone(&inner);
        let reader = thread::Builder::new()
            .name("evalcli-read".into())
            .spawn(move || reader_loop(read_half, rx_inner))?;
        Ok(RemoteEvalClient { inner, reader: Mutex::new(Some(reader)) })
    }

    /// Send one request; the returned slot resolves when its response
    /// arrives (FIFO).
    fn send(&self, req: &Request) -> Arc<ReplySlot> {
        let slot = Arc::new(ReplySlot::default());
        if self.inner.dead.load(Ordering::SeqCst) {
            slot.fill(Err("connection to eval server is closed".into()));
            return slot;
        }
        let payload = req.encode();
        let mut w = self.inner.writer.lock().unwrap();
        // push under the writer lock: slot order == frame order, and
        // the slot is queued before the server can possibly answer
        self.inner.pending.lock().unwrap().push_back(Arc::clone(&slot));
        let sent = proto::write_frame(&mut *w, &payload);
        if let Err(e) = sent {
            // the server will never answer this frame, so retract the
            // slot — it is still the newest entry (pushes are serialized
            // by the writer lock we hold, and responses only exist for
            // *written* requests) — or FIFO matching would hand the next
            // response to this dead slot and hang its real owner
            {
                let mut pending = self.inner.pending.lock().unwrap();
                if pending.back().is_some_and(|s| Arc::ptr_eq(s, &slot)) {
                    pending.pop_back();
                }
            }
            // a frame rejected by the size guard never touched the
            // socket — the connection stays usable; anything else may
            // have written a partial frame, which is unrecoverable
            if e.kind() != io::ErrorKind::InvalidInput {
                self.inner.dead.store(true, Ordering::SeqCst);
            }
            slot.fill(Err(format!("send failed: {e}")));
        }
        drop(w);
        slot
    }

    /// Send and block for the matched response.
    fn request(&self, req: &Request) -> Result<Response, String> {
        self.send(req).wait()
    }

    /// Send and unwrap one expected response variant: classified server
    /// errors and variant mismatches both become the `Err` string, in
    /// one place for every typed endpoint below.
    fn expect<T>(
        &self,
        req: &Request,
        what: &'static str,
        extract: impl FnOnce(Response) -> Result<T, Response>,
    ) -> Result<T, String> {
        match self.request(req)? {
            Response::Error { kind, msg } => Err(format!("{kind} error: {msg}")),
            resp => extract(resp).map_err(|other| {
                format!("expected {what}, got {}", other.kind_name())
            }),
        }
    }

    fn expect_spec_info(
        &self,
        req: &Request,
    ) -> Result<(u32, String, MachineSpec), String> {
        self.expect(req, "spec-info", |r| match r {
            Response::SpecInfo { id, name, spec } => Ok((id, name, spec)),
            other => Err(other),
        })
    }

    /// Liveness probe (also a cheap protocol handshake check).
    pub fn ping(&self) -> Result<(), String> {
        self.expect(&Request::Ping, "pong", |r| match r {
            Response::Pong => Ok(()),
            other => Err(other),
        })
    }

    /// Register (or alias) a machine spec in the server's registry;
    /// returns the server-side spec id.
    pub fn register_spec(&self, name: &str, spec: &MachineSpec) -> Result<u32, String> {
        self.expect_spec_info(&Request::RegisterSpec {
            name: name.to_string(),
            spec: spec.clone(),
        })
        .map(|(id, _, _)| id)
    }

    /// Look up a registered spec by name: `(id, copy of the spec)`.
    pub fn spec(&self, name: &str) -> Result<(u32, MachineSpec), String> {
        self.expect_spec_info(&Request::GetSpec { name: name.to_string() })
            .map(|(id, _, spec)| (id, spec))
    }

    /// Asynchronous evaluation: returns a ticket immediately; any
    /// number may be in flight on this one connection.
    pub fn submit(
        &self,
        spec: SpecRef,
        scenario: Scenario,
        dsl: String,
        mode: ExecMode,
        priority: u8,
    ) -> RemoteTicket {
        let slot = self.send(&Request::Eval(WireEvalRequest {
            spec,
            scenario,
            dsl,
            mode,
            priority,
        }));
        RemoteTicket { slot }
    }

    /// Synchronous evaluation through the server's shared caches (the
    /// remote mirror of `EvalService::evaluate`).
    pub fn evaluate(
        &self,
        spec: SpecRef,
        scenario: Scenario,
        dsl: &str,
        mode: ExecMode,
        priority: u8,
    ) -> SystemFeedback {
        self.submit(spec, scenario, dsl.to_string(), mode, priority).wait()
    }

    /// Server-side [`StatsSnapshot`] (counters live with the service,
    /// not the client).
    pub fn stats(&self) -> Result<StatsSnapshot, String> {
        self.expect(&Request::Stats, "stats", |r| match r {
            Response::Stats(s) => Ok(s),
            other => Err(other),
        })
    }

    /// The server's human-readable `summary()` block.
    pub fn summary(&self) -> Result<String, String> {
        self.expect(&Request::Summary, "summary", |r| match r {
            Response::Summary(s) => Ok(s),
            other => Err(other),
        })
    }
}

impl Drop for RemoteEvalClient {
    fn drop(&mut self) {
        self.inner.dead.store(true, Ordering::SeqCst);
        if let Ok(w) = self.inner.writer.lock() {
            let _ = w.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.reader.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

fn reader_loop(mut stream: TcpStream, inner: Arc<ClientInner>) {
    let close_msg;
    loop {
        let result = match proto::read_frame(&mut stream) {
            Ok(Some(payload)) => {
                Response::decode(&payload).map_err(|e| e.to_string())
            }
            Ok(None) => {
                close_msg = "connection to eval server is closed".to_string();
                break;
            }
            Err(e) => {
                close_msg = format!("connection to eval server failed: {e}");
                break;
            }
        };
        let slot = inner.pending.lock().unwrap().pop_front();
        match slot {
            Some(s) => s.fill(result),
            None => {
                // a frame with no awaiting request: either the server
                // refused us up front (e.g. connection-capacity errors
                // are sent before any request — surface that message),
                // or the stream is out of sync beyond repair; tear the
                // connection down either way
                close_msg = match result {
                    Ok(Response::Error { kind, msg }) => {
                        format!("eval server refused the connection ({kind}): {msg}")
                    }
                    _ => "eval server sent an unsolicited response".to_string(),
                };
                break;
            }
        }
    }
    inner.dead.store(true, Ordering::SeqCst);
    let _ = stream.shutdown(Shutdown::Both);
    inner.fail_all_pending(&close_msg);
}
