//! The remote eval client: the cross-process face of
//! [`EvalService`](crate::coordinator::EvalService), with the same
//! `evaluate` / `submit`-plus-ticket shape — so campaigns drive a
//! remote backend exactly like an in-process one (the
//! `Coordinator`-compatible adapter is
//! [`Coordinator::remote`](crate::coordinator::Coordinator::remote)).
//!
//! One socket carries any number of in-flight requests: a manager
//! thread owns the write half and the request queue, a per-connection
//! reader thread matches responses FIFO, and user calls only enqueue.
//!
//! # Wire batching
//!
//! Evaluations that are adjacent in the send queue coalesce into one
//! [`Request::EvalBatch`] frame (up to
//! [`proto::MAX_BATCH_ITEMS`](super::proto::MAX_BATCH_ITEMS) items), so
//! a proposer submitting K candidates pays one syscall round-trip
//! instead of K — [`RemoteEvalClient::submit_batch`] guarantees the
//! coalescing, and pipelined [`RemoteEvalClient::submit`] calls get it
//! opportunistically.  The server answers per item; the reader unpacks
//! the [`Response::FeedbackBatch`] back onto the individual tickets,
//! re-scheduling *individually* shed items through the normal retry
//! path, so batching is invisible to callers (and bit-identical to
//! frame-per-eval submission).  A pre-batch server classifies the
//! unknown tag as a retryable `Decode` error; the client then disables
//! batching for the connection's lifetime and replays the items as
//! single frames — new clients interoperate with old servers
//! transparently.  `MAPPEROPT_WIRE_BATCH=0` (or
//! [`RemoteEvalClient::set_wire_batching`]) turns coalescing off.
//!
//! # Fault tolerance
//!
//! The client survives a flaky wire instead of reporting it.  Every
//! request carries a [`RetryPolicy`] budget: retryable failures —
//! transport errors, connection drops, and retryable classified server
//! errors ([`ErrorKind::is_retryable`](super::proto::ErrorKind::is_retryable):
//! framing, checksum corruption, version skew,
//! [`ErrorKind::Overloaded`](super::proto::ErrorKind::Overloaded)
//! shedding) — requeue the
//! request with bounded exponential backoff (deterministic seeded
//! jitter, `Overloaded` retry-after hints respected), while the manager
//! redials the server.  Replay is safe because evaluations are pure
//! (keyed by the same fingerprints the server caches use); after every
//! reconnect a synthetic `Ping` handshake must succeed before *any*
//! queued request — in particular a non-idempotent `RegisterSpec` — is
//! replayed.  A request that exhausts its budget or per-request
//! deadline resolves with a classified `Remote ... error` execution
//! error; nothing ever hangs, and terminal server errors
//! (`BadRequest` / `Internal`) are never retried.  [`RemoteEvalClient::stats`]
//! overlays this client's `retries` / `reconnects` counters onto the
//! server's snapshot.
//!
//! # Fleet fronts
//!
//! The client neither knows nor cares whether [`RemoteEvalClient::peer`]
//! is a single [`EvalServer`](super::EvalServer) or an
//! [`EvalRouter`](super::EvalRouter) fronting a sharded fleet — the
//! wire protocol is identical.  The fleet properties ride on machinery
//! this module already has: a shard dying mid-request surfaces as a
//! retryable `Overloaded` answer (the router's failover), which the
//! retry path replays onto the re-formed ring exactly like a shed; and
//! `stats()` against a router returns the *fleet-aggregate* snapshot,
//! per-shard contributions included in
//! [`StatsSnapshot::shards`](crate::coordinator::StatsSnapshot).

use std::collections::VecDeque;
use std::io;
use std::net::{Shutdown, SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::StatsSnapshot;
use crate::feedback::SystemFeedback;
use crate::machine::MachineSpec;
use crate::obs::{merge_stage_hists, SpanRecord, Stage, StageSet, TraceIdGen};
use crate::sim::ExecMode;
use crate::util::rng::Rng;

use super::proto::{
    self, BatchItem, ErrorKind, Request, Response, Scenario, SpecRef,
    WireEvalRequest,
};

/// Retry discipline for one client: how long a request may take end to
/// end, how many transmission attempts it gets, and how re-attempts
/// back off.  [`RetryPolicy::default`] reads the budget from
/// `MAPPEROPT_RETRY_BUDGET` (default 4, minimum 1).
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Per-request wall-clock deadline, enqueue to response.
    pub deadline: Duration,
    /// Maximum transmission attempts per request (>= 1; the first send
    /// counts as one).
    pub budget: u32,
    /// First re-attempt delay; doubles per attempt.
    pub backoff_base: Duration,
    /// Ceiling on any single backoff delay.
    pub backoff_cap: Duration,
    /// Seed for the jitter RNG — equal seeds give bit-identical retry
    /// schedules, which the chaos tests rely on.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        let budget = std::env::var("MAPPEROPT_RETRY_BUDGET")
            .ok()
            .and_then(|v| v.parse::<u32>().ok())
            .unwrap_or(4)
            .max(1);
        RetryPolicy {
            deadline: Duration::from_secs(120),
            budget,
            backoff_base: Duration::from_millis(25),
            backoff_cap: Duration::from_secs(1),
            seed: 0x7E57_0BED_5EED_CAFE,
        }
    }
}

/// One awaited response slot (FIFO-matched by the reader thread).
#[derive(Default)]
struct ReplySlot {
    done: Mutex<Option<Result<Response, String>>>,
    cv: Condvar,
    /// When armed, the first fill records one `ClientSend` sample —
    /// submission to resolution, retries and reconnects included — into
    /// the client's stage set.  Armed for evaluations only.
    obs: Mutex<Option<(Instant, Arc<StageSet>)>>,
}

impl ReplySlot {
    /// Arm the `ClientSend` measurement (before the request is
    /// enqueued, so the sample covers the full client-side path).
    fn observe(&self, started: Instant, stages: Arc<StageSet>) {
        *self.obs.lock().unwrap() = Some((started, stages));
    }

    /// First fill wins (a retry path and a teardown drain can race;
    /// both classify, so either order is correct).
    fn fill(&self, r: Result<Response, String>) {
        let mut g = self.done.lock().unwrap();
        if g.is_none() {
            if let Some((t0, stages)) = self.obs.lock().unwrap().take() {
                stages.record_since(Stage::ClientSend, t0);
            }
            *g = Some(r);
            self.cv.notify_all();
        }
    }

    fn wait(&self) -> Result<Response, String> {
        let mut g = self.done.lock().unwrap();
        loop {
            if let Some(r) = g.as_ref() {
                return r.clone();
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    fn poll(&self) -> Option<Result<Response, String>> {
        self.done.lock().unwrap().clone()
    }
}

/// One queued or in-flight request with its retry bookkeeping.  Lives
/// in the manager's queue until written, then in the connection's
/// `inflight` deque until answered; a failure path moves it back.
struct Pending {
    req: Request,
    slot: Arc<ReplySlot>,
    /// Transmission attempts so far (charged at write and at failed
    /// dials — a server that cannot be reached burns budget too).
    attempts: u32,
    /// Absolute end-to-end deadline.
    deadline: Instant,
    /// Backoff gate: not re-sent before this instant.
    not_before: Instant,
    /// Last failure, echoed in the terminal classification.
    last_err: String,
    /// The post-reconnect `Ping` gate; its slot has no waiter.
    handshake: bool,
    /// Whether this request may coalesce into an `EvalBatch` frame
    /// (cleared when a specific batch attempt could not be framed, so
    /// the replay goes out as a single frame).
    batch_ok: bool,
}

/// One *frame* on the wire awaiting its answer: a single request, or a
/// coalesced `EvalBatch` whose answer must be a `FeedbackBatch` of
/// equal length.  The connection's in-flight deque holds these — FIFO
/// matching is per frame, fan-out back to slots is per part.
struct Written {
    parts: Vec<Pending>,
    /// True iff the frame was a `Request::EvalBatch`.
    batch: bool,
}

/// Reader-to-manager events (plus user submissions).
enum Event {
    Send(Pending),
    /// An atomic multi-submission ([`RemoteEvalClient::submit_batch`]):
    /// enqueued back-to-back so the pump coalesces them into one frame.
    SendMany(Vec<Pending>),
    /// A retryable classified response; `pending` was popped from the
    /// in-flight deque and must be rescheduled.
    Retry { pending: Pending, hint_ms: u64, reason: String },
    /// A whole batch frame failed retryably (e.g. a pre-batch server
    /// classified the unknown tag as `Decode`): reschedule every part;
    /// with `disable_batching` the replay — and everything after it —
    /// goes out as single frames.
    BatchFailed {
        parts: Vec<Pending>,
        hint_ms: u64,
        reason: String,
        disable_batching: bool,
    },
    /// The handshake `Ping` resolved (`ok` = got `Pong`).
    HandshakeDone { epoch: u64, ok: bool, msg: String },
    /// Connection `epoch` is unusable; the manager drains and redials.
    ConnDead { epoch: u64, msg: String },
    /// Client drop: fail everything, join, exit.
    Shutdown,
}

/// State shared between user-facing handles and the manager.
struct Shared {
    /// Set on drop/teardown; new sends fail fast.
    dead: AtomicBool,
    retries: AtomicU64,
    reconnects: AtomicU64,
    /// `EvalBatch` frames written (telemetry; the differential tests
    /// assert batching actually happened).
    batched_frames: AtomicU64,
    /// Live batching switch: env default, user override, or the
    /// old-server fallback clearing it permanently.
    batching: AtomicBool,
    /// Live tracing switch ([`RemoteEvalClient::set_tracing`]): when
    /// set, evaluations are stamped with ids from `trace_ids` and their
    /// replies carry the server's per-eval telemetry rider.
    tracing: AtomicBool,
    trace_ids: TraceIdGen,
    /// Client-side stage samples (`ClientSend`: submission to
    /// resolution); overlaid onto [`RemoteEvalClient::stats`] the same
    /// way the retry counters are.
    stages: Arc<StageSet>,
}

/// Completion handle of one remote submission — the wire twin of
/// [`EvalTicket`](crate::coordinator::EvalTicket).
pub struct RemoteTicket {
    slot: Arc<ReplySlot>,
}

impl RemoteTicket {
    /// Block until the server answers (or the retry budget is
    /// exhausted); every non-feedback outcome is classified as an
    /// execution error, so campaign code never sees a second error
    /// channel.
    pub fn wait(&self) -> SystemFeedback {
        feedback_of(self.slot.wait())
    }

    /// Non-blocking check; `Some` once the response arrived.
    pub fn poll(&self) -> Option<SystemFeedback> {
        self.slot.poll().map(feedback_of)
    }

    pub fn is_done(&self) -> bool {
        self.slot.done.lock().unwrap().is_some()
    }
}

fn feedback_of(r: Result<Response, String>) -> SystemFeedback {
    match r {
        Ok(Response::Feedback(fb)) => fb,
        Ok(Response::Error { kind, msg, .. }) => {
            SystemFeedback::ExecutionError(format!("Remote {kind} error: {msg}"))
        }
        Ok(other) => SystemFeedback::ExecutionError(format!(
            "Remote protocol error: expected feedback, got {}",
            other.kind_name()
        )),
        Err(e) => SystemFeedback::ExecutionError(format!("Remote transport error: {e}")),
    }
}

/// A fault-tolerant connection to a remote
/// [`EvalServer`](super::EvalServer) (see module docs).
pub struct RemoteEvalClient {
    /// Mutex-wrapped so the client is `Sync` on every supported
    /// toolchain (`mpsc::Sender` itself only became `Sync` later).
    tx: Mutex<mpsc::Sender<Event>>,
    shared: Arc<Shared>,
    policy: RetryPolicy,
    peer: SocketAddr,
    manager: Mutex<Option<thread::JoinHandle<()>>>,
}

impl RemoteEvalClient {
    /// Connect with [`RetryPolicy::default`] and start the manager and
    /// reader threads.  The dial is eager: an unreachable address fails
    /// here, not on first use.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<RemoteEvalClient> {
        Self::connect_with(addr, RetryPolicy::default())
    }

    /// [`RemoteEvalClient::connect`] with an explicit [`RetryPolicy`].
    pub fn connect_with<A: ToSocketAddrs>(
        addr: A,
        policy: RetryPolicy,
    ) -> io::Result<RemoteEvalClient> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        // the resolved peer is what reconnects redial — resolution
        // happens once, so retry behavior does not depend on DNS luck
        let peer = stream.peer_addr()?;
        let batching = std::env::var("MAPPEROPT_WIRE_BATCH")
            .map(|v| v != "0")
            .unwrap_or(true);
        let tracing = std::env::var("MAPPEROPT_TRACE")
            .map(|v| v != "0" && !v.is_empty())
            .unwrap_or(false);
        let shared = Arc::new(Shared {
            dead: AtomicBool::new(false),
            retries: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            batched_frames: AtomicU64::new(0),
            batching: AtomicBool::new(batching),
            tracing: AtomicBool::new(tracing),
            trace_ids: TraceIdGen::new(),
            stages: Arc::new(StageSet::new()),
        });
        let (tx, rx) = mpsc::channel::<Event>();
        let mut mgr = Manager {
            peer,
            policy: policy.clone(),
            rx,
            tx: tx.clone(),
            shared: Arc::clone(&shared),
            queue: VecDeque::new(),
            conn: None,
            epoch: 0,
            handshaking: false,
            rng: Rng::new(policy.seed),
            dial_fails: 0,
            dial_not_before: Instant::now(),
        };
        mgr.install(stream, false);
        let manager = thread::Builder::new()
            .name("evalcli-mgr".into())
            .spawn(move || mgr.run())?;
        Ok(RemoteEvalClient {
            tx: Mutex::new(tx),
            shared,
            policy,
            peer,
            manager: Mutex::new(Some(manager)),
        })
    }

    /// The resolved address this client dials (and redials) — a single
    /// server or a fleet's router front, indistinguishably.
    pub fn peer(&self) -> SocketAddr {
        self.peer
    }

    /// Total re-transmissions this client has performed.
    pub fn retries(&self) -> u64 {
        self.shared.retries.load(Ordering::SeqCst)
    }

    /// Successful reconnect handshakes after the initial dial.
    pub fn reconnects(&self) -> u64 {
        self.shared.reconnects.load(Ordering::SeqCst)
    }

    /// `EvalBatch` frames this client has put on the wire.
    pub fn batched_frames(&self) -> u64 {
        self.shared.batched_frames.load(Ordering::SeqCst)
    }

    /// Turn wire batching on or off (default: on, unless
    /// `MAPPEROPT_WIRE_BATCH=0`).  Purely a transport choice — results
    /// are bit-identical either way.
    pub fn set_wire_batching(&self, on: bool) {
        self.shared.batching.store(on, Ordering::SeqCst);
    }

    /// Turn request tracing on or off (default: off, unless
    /// `MAPPEROPT_TRACE=1`).  Traced evaluations carry a client-stamped
    /// trace id on the wire; the server records a span per traced eval
    /// (dumpable via [`RemoteEvalClient::trace_dump`]) and returns the
    /// per-eval telemetry rider on the reply.  Tracing is *inert*:
    /// evaluation results are bit-identical either way.
    pub fn set_tracing(&self, on: bool) {
        self.shared.tracing.store(on, Ordering::SeqCst);
    }

    /// Whether evaluations are currently stamped with trace ids.
    pub fn tracing(&self) -> bool {
        self.shared.tracing.load(Ordering::SeqCst)
    }

    /// A fresh trace id when tracing is on, else 0 (= untraced on the
    /// wire).
    fn next_trace_id(&self) -> u64 {
        if self.tracing() {
            self.shared.trace_ids.next()
        } else {
            0
        }
    }

    /// Enqueue one request; the returned slot resolves when a response
    /// arrives or the retry budget / deadline is exhausted.
    fn send(&self, req: Request) -> Arc<ReplySlot> {
        let slot = Arc::new(ReplySlot::default());
        if matches!(req, Request::Eval(_)) {
            slot.observe(Instant::now(), Arc::clone(&self.shared.stages));
        }
        if self.shared.dead.load(Ordering::SeqCst) {
            slot.fill(Err("connection to eval server is closed".into()));
            return slot;
        }
        let now = Instant::now();
        let pending = Pending {
            req,
            slot: Arc::clone(&slot),
            attempts: 0,
            deadline: now + self.policy.deadline,
            not_before: now,
            last_err: String::new(),
            handshake: false,
            batch_ok: true,
        };
        let sent = self.tx.lock().unwrap().send(Event::Send(pending));
        if sent.is_err() {
            slot.fill(Err("connection to eval server is closed".into()));
        }
        slot
    }

    /// Send and block for the matched response.
    fn request(&self, req: Request) -> Result<Response, String> {
        self.send(req).wait()
    }

    /// Send and unwrap one expected response variant: classified server
    /// errors and variant mismatches both become the `Err` string, in
    /// one place for every typed endpoint below.
    fn expect<T>(
        &self,
        req: Request,
        what: &'static str,
        extract: impl FnOnce(Response) -> Result<T, Response>,
    ) -> Result<T, String> {
        match self.request(req)? {
            Response::Error { kind, msg, .. } => Err(format!("{kind} error: {msg}")),
            resp => extract(resp).map_err(|other| {
                format!("expected {what}, got {}", other.kind_name())
            }),
        }
    }

    fn expect_spec_info(
        &self,
        req: Request,
    ) -> Result<(u32, String, MachineSpec), String> {
        self.expect(req, "spec-info", |r| match r {
            Response::SpecInfo { id, name, spec } => Ok((id, name, spec)),
            other => Err(other),
        })
    }

    /// Liveness probe (also a cheap protocol handshake check).
    pub fn ping(&self) -> Result<(), String> {
        self.expect(Request::Ping, "pong", |r| match r {
            Response::Pong => Ok(()),
            other => Err(other),
        })
    }

    /// Register (or alias) a machine spec in the server's registry;
    /// returns the server-side spec id.
    pub fn register_spec(&self, name: &str, spec: &MachineSpec) -> Result<u32, String> {
        self.expect_spec_info(Request::RegisterSpec {
            name: name.to_string(),
            spec: spec.clone(),
        })
        .map(|(id, _, _)| id)
    }

    /// Look up a registered spec by name: `(id, copy of the spec)`.
    pub fn spec(&self, name: &str) -> Result<(u32, MachineSpec), String> {
        self.expect_spec_info(Request::GetSpec { name: name.to_string() })
            .map(|(id, _, spec)| (id, spec))
    }

    /// Asynchronous evaluation: returns a ticket immediately; any
    /// number may be in flight on this one connection.
    pub fn submit(
        &self,
        spec: SpecRef,
        scenario: Scenario,
        dsl: String,
        mode: ExecMode,
        priority: u8,
    ) -> RemoteTicket {
        let slot = self.send(Request::Eval(WireEvalRequest {
            spec,
            scenario,
            dsl,
            mode,
            priority,
            trace_id: self.next_trace_id(),
        }));
        RemoteTicket { slot }
    }

    /// Submit many evaluations at once, one ticket per item (in input
    /// order).  The items are enqueued atomically, so with batching on
    /// they travel as `EvalBatch` frames — one syscall round-trip per
    /// [`proto::MAX_BATCH_ITEMS`](super::proto::MAX_BATCH_ITEMS) items —
    /// while each item still sheds, retries, and resolves individually.
    pub fn submit_batch(&self, mut reqs: Vec<WireEvalRequest>) -> Vec<RemoteTicket> {
        // stamp unstamped items when tracing is on (caller-provided ids
        // are kept, so a campaign can correlate its own way)
        for q in &mut reqs {
            if q.trace_id == 0 {
                q.trace_id = self.next_trace_id();
            }
        }
        let slots: Vec<Arc<ReplySlot>> = reqs
            .iter()
            .map(|_| {
                let slot = Arc::new(ReplySlot::default());
                slot.observe(Instant::now(), Arc::clone(&self.shared.stages));
                slot
            })
            .collect();
        if self.shared.dead.load(Ordering::SeqCst) {
            for s in &slots {
                s.fill(Err("connection to eval server is closed".into()));
            }
        } else if !reqs.is_empty() {
            let now = Instant::now();
            let parts: Vec<Pending> = reqs
                .into_iter()
                .zip(&slots)
                .map(|(q, slot)| Pending {
                    req: Request::Eval(q),
                    slot: Arc::clone(slot),
                    attempts: 0,
                    deadline: now + self.policy.deadline,
                    not_before: now,
                    last_err: String::new(),
                    handshake: false,
                    batch_ok: true,
                })
                .collect();
            let sent = self.tx.lock().unwrap().send(Event::SendMany(parts));
            if sent.is_err() {
                for s in &slots {
                    s.fill(Err("connection to eval server is closed".into()));
                }
            }
        }
        slots.into_iter().map(|slot| RemoteTicket { slot }).collect()
    }

    /// Synchronous evaluation through the server's shared caches (the
    /// remote mirror of `EvalService::evaluate`).
    pub fn evaluate(
        &self,
        spec: SpecRef,
        scenario: Scenario,
        dsl: &str,
        mode: ExecMode,
        priority: u8,
    ) -> SystemFeedback {
        self.submit(spec, scenario, dsl.to_string(), mode, priority).wait()
    }

    /// Server-side [`StatsSnapshot`] with this client's `retries` /
    /// `reconnects` counters and `client` stage histogram overlaid (the
    /// server zero-fills them: the client is the only party that can
    /// observe its own wire).
    pub fn stats(&self) -> Result<StatsSnapshot, String> {
        let mut snap = self.expect(Request::Stats, "stats", |r| match r {
            Response::Stats(s) => Ok(s),
            other => Err(other),
        })?;
        snap.retries = self.retries();
        snap.reconnects = self.reconnects();
        merge_stage_hists(&mut snap.stage_hists, &self.shared.stages.snapshots());
        Ok(snap)
    }

    /// Drain the server's flight recorder: the spans of recently
    /// completed traced (or slow, or failed) evaluations, oldest first.
    /// Against a router front this returns every shard's spans followed
    /// by the router's own.
    pub fn trace_dump(&self) -> Result<Vec<SpanRecord>, String> {
        self.expect(Request::TraceDump, "trace-dump", |r| match r {
            Response::TraceDump(spans) => Ok(spans),
            other => Err(other),
        })
    }

    /// The server's human-readable `summary()` block.
    pub fn summary(&self) -> Result<String, String> {
        self.expect(Request::Summary, "summary", |r| match r {
            Response::Summary(s) => Ok(s),
            other => Err(other),
        })
    }
}

impl Drop for RemoteEvalClient {
    /// Tear down without leaking: fail every queued and in-flight slot
    /// (dropping tickets mid-flight never strands their waiters), close
    /// the socket, and join the manager (which joins its reader).
    fn drop(&mut self) {
        self.shared.dead.store(true, Ordering::SeqCst);
        let _ = self.tx.lock().unwrap().send(Event::Shutdown);
        if let Some(h) = self.manager.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// One live connection: the write half, the FIFO of written-and-
/// unanswered requests, and the reader matching responses to it.
struct Conn {
    stream: TcpStream,
    inflight: Arc<Mutex<VecDeque<Written>>>,
    reader: Option<thread::JoinHandle<()>>,
    epoch: u64,
}

/// The manager thread: owns dialing, writing, retry scheduling, and
/// teardown.  Single-threaded over all of it, so frame order always
/// equals in-flight slot order and no lock ordering is needed.
struct Manager {
    peer: SocketAddr,
    policy: RetryPolicy,
    rx: mpsc::Receiver<Event>,
    tx: mpsc::Sender<Event>,
    shared: Arc<Shared>,
    /// Requests waiting to be (re)written, each gated by `not_before`.
    queue: VecDeque<Pending>,
    conn: Option<Conn>,
    /// Bumped per established connection; events from dead readers
    /// carry their epoch and are ignored when stale.
    epoch: u64,
    /// True between a reconnect and its `Ping` handshake resolving; no
    /// request is replayed while set.
    handshaking: bool,
    rng: Rng,
    /// Consecutive failed dials (drives dial backoff; reset on
    /// handshake success).
    dial_fails: u32,
    dial_not_before: Instant,
}

impl Manager {
    fn run(mut self) {
        'main: loop {
            self.expire();
            self.redial();
            self.pump();
            let timeout = self.next_wakeup();
            match self.rx.recv_timeout(timeout) {
                Ok(Event::Shutdown) => break,
                Ok(ev) => {
                    self.handle(ev);
                    // drain whatever else is queued before pumping, so
                    // a burst of submissions coalesces into batch
                    // frames instead of going out one frame per event
                    while let Ok(ev) = self.rx.try_recv() {
                        if matches!(ev, Event::Shutdown) {
                            break 'main;
                        }
                        self.handle(ev);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        self.teardown();
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::Send(p) => self.queue.push_back(p),
            Event::SendMany(ps) => self.queue.extend(ps),
            Event::BatchFailed { parts, hint_ms, reason, disable_batching } => {
                if disable_batching {
                    // a server that cannot decode the batch tag never
                    // will: fall back to single frames for good
                    self.shared.batching.store(false, Ordering::SeqCst);
                }
                let now = Instant::now();
                for mut p in parts {
                    let backoff = self
                        .backoff(p.attempts)
                        .max(Duration::from_millis(hint_ms));
                    p.not_before = now + backoff;
                    p.last_err.clone_from(&reason);
                    self.queue.push_back(p);
                }
            }
            Event::Retry { mut pending, hint_ms, reason } => {
                // server-classified retryable failure: back off at
                // least as long as the server's retry-after hint
                let backoff = self
                    .backoff(pending.attempts)
                    .max(Duration::from_millis(hint_ms));
                pending.not_before = Instant::now() + backoff;
                pending.last_err = reason;
                self.queue.push_back(pending);
            }
            Event::HandshakeDone { epoch, ok, msg } => {
                if self.conn.as_ref().map(|c| c.epoch) != Some(epoch) {
                    return; // stale
                }
                if ok {
                    self.handshaking = false;
                    self.dial_fails = 0;
                    self.shared.reconnects.fetch_add(1, Ordering::SeqCst);
                } else {
                    self.kill_conn(&msg);
                }
            }
            Event::ConnDead { epoch, msg } => {
                if self.conn.as_ref().map(|c| c.epoch) == Some(epoch) {
                    self.kill_conn(&msg);
                }
            }
            Event::Shutdown => unreachable!("handled in run()"),
        }
    }

    /// Deterministic half-jittered exponential backoff: half the capped
    /// exponential delay is fixed, half is drawn from the seeded RNG.
    fn backoff(&mut self, attempt: u32) -> Duration {
        let base = (self.policy.backoff_base.as_millis() as u64).max(1);
        let cap = (self.policy.backoff_cap.as_millis() as u64).max(base);
        let full = base.saturating_mul(1u64 << attempt.min(16)).min(cap);
        let jitter = self.rng.below(full as usize / 2 + 1) as u64;
        Duration::from_millis(full / 2 + jitter)
    }

    /// Fail queued requests whose deadline passed, and sever the
    /// connection if the oldest in-flight request is past its deadline
    /// (the reader is blocked on the socket, so expiry must cut the
    /// socket — the conn-death drain then classifies it).
    fn expire(&mut self) {
        let now = Instant::now();
        let mut i = 0;
        while i < self.queue.len() {
            if now >= self.queue[i].deadline {
                let p = self.queue.remove(i).unwrap();
                fail(
                    &p,
                    &format!(
                        "request deadline of {:?} exceeded after {} attempts",
                        self.policy.deadline, p.attempts
                    ),
                );
            } else {
                i += 1;
            }
        }
        let stalled = self.conn.as_ref().is_some_and(|c| {
            c.inflight
                .lock()
                .unwrap()
                .front()
                .is_some_and(|w| w.parts.iter().any(|p| now >= p.deadline))
        });
        if stalled {
            self.kill_conn("request deadline exceeded awaiting a response");
        }
    }

    /// Tear down the current connection and reschedule its in-flight
    /// requests (in order, ahead of the queue) for replay.
    fn kill_conn(&mut self, msg: &str) {
        let Some(mut conn) = self.conn.take() else { return };
        let _ = conn.stream.shutdown(Shutdown::Both);
        if let Some(h) = conn.reader.take() {
            let _ = h.join();
        }
        self.handshaking = false;
        let drained: Vec<Written> = {
            let mut g = conn.inflight.lock().unwrap();
            g.drain(..).collect()
        };
        for w in drained.into_iter().rev() {
            for mut p in w.parts.into_iter().rev() {
                if p.handshake {
                    continue; // the gate dies with its connection
                }
                p.last_err = msg.to_string();
                p.not_before = Instant::now(); // replay is gated by redial
                self.queue.push_front(p);
            }
        }
        self.dial_fails = self.dial_fails.saturating_add(1);
        let wait = self.backoff(self.dial_fails);
        self.dial_not_before = Instant::now() + wait;
    }

    /// Dial the peer again if there is work and the dial backoff has
    /// elapsed; a failed dial charges one attempt to every queued
    /// request, so an unreachable server exhausts budgets instead of
    /// retrying forever.
    fn redial(&mut self) {
        if self.conn.is_some()
            || self.queue.is_empty()
            || Instant::now() < self.dial_not_before
        {
            return;
        }
        match TcpStream::connect(self.peer) {
            Ok(stream) => {
                let _ = stream.set_nodelay(true);
                self.install(stream, true);
            }
            Err(e) => {
                let msg = format!("connection to eval server failed: {e}");
                let mut i = 0;
                while i < self.queue.len() {
                    let p = &mut self.queue[i];
                    p.attempts += 1;
                    p.last_err.clone_from(&msg);
                    if p.attempts >= self.policy.budget {
                        let p = self.queue.remove(i).unwrap();
                        fail(
                            &p,
                            &format!(
                                "retry budget of {} attempts exhausted: {}",
                                self.policy.budget, p.last_err
                            ),
                        );
                    } else {
                        i += 1;
                    }
                }
                self.dial_fails = self.dial_fails.saturating_add(1);
                let wait = self.backoff(self.dial_fails);
                self.dial_not_before = Instant::now() + wait;
            }
        }
    }

    /// Adopt an established stream: spawn its reader and, on
    /// reconnects, write the `Ping` handshake that gates replay.
    fn install(&mut self, stream: TcpStream, reconnect: bool) {
        self.epoch += 1;
        let epoch = self.epoch;
        let inflight = Arc::new(Mutex::new(VecDeque::new()));
        let read_half = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => {
                self.dial_fails = self.dial_fails.saturating_add(1);
                let wait = self.backoff(self.dial_fails);
                self.dial_not_before = Instant::now() + wait;
                return;
            }
        };
        let rd_inflight = Arc::clone(&inflight);
        let rd_tx = self.tx.clone();
        let reader = thread::Builder::new()
            .name("evalcli-read".into())
            .spawn(move || reader_loop(read_half, rd_inflight, rd_tx, epoch));
        let Ok(reader) = reader else { return };
        let mut conn = Conn { stream, inflight, reader: Some(reader), epoch };
        self.handshaking = false;
        if reconnect {
            // gate replay behind a fresh Ping: nothing — least of all a
            // non-idempotent RegisterSpec — is re-sent until the server
            // proves it is answering this connection
            let now = Instant::now();
            let gate = Pending {
                req: Request::Ping,
                slot: Arc::new(ReplySlot::default()),
                attempts: 0,
                deadline: now + self.policy.deadline,
                not_before: now,
                last_err: String::new(),
                handshake: true,
                batch_ok: false,
            };
            let payload = gate.req.encode();
            conn.inflight
                .lock()
                .unwrap()
                .push_back(Written { parts: vec![gate], batch: false });
            self.handshaking = true;
            if proto::write_frame(&mut conn.stream, &payload).is_err() {
                self.conn = Some(conn);
                self.kill_conn("connection to eval server failed during handshake");
                return;
            }
        }
        self.conn = Some(conn);
    }

    /// Write every eligible queued request to the live connection
    /// (skipping backoff-gated entries), charging attempts and failing
    /// budget-exhausted requests.  Adjacent eligible evaluations
    /// coalesce into one `EvalBatch` frame when batching is on.
    fn pump(&mut self) {
        if self.handshaking {
            return;
        }
        let now = Instant::now();
        let batching = self.shared.batching.load(Ordering::SeqCst);
        let mut i = 0;
        while i < self.queue.len() {
            if self.conn.is_none() {
                return;
            }
            if self.queue[i].not_before > now {
                i += 1;
                continue;
            }
            let p = self.queue.remove(i).unwrap();
            if p.attempts >= self.policy.budget {
                fail(
                    &p,
                    &format!(
                        "retry budget of {} attempts exhausted: {}",
                        self.policy.budget, p.last_err
                    ),
                );
                continue;
            }
            // coalesce the run of adjacent, eligible evals behind this
            // one; a conservative size estimate keeps the combined
            // frame far below MAX_FRAME_LEN
            let mut parts = vec![p];
            if batching && batchable(&parts[0]) {
                let mut est = frame_estimate(&parts[0].req);
                while parts.len() < proto::MAX_BATCH_ITEMS {
                    let eligible = self.queue.get(i).is_some_and(|q| {
                        batchable(q)
                            && q.not_before <= now
                            && est + frame_estimate(&q.req) <= (1 << 20)
                    });
                    if !eligible {
                        break;
                    }
                    let q = self.queue.remove(i).unwrap();
                    if q.attempts >= self.policy.budget {
                        fail(
                            &q,
                            &format!(
                                "retry budget of {} attempts exhausted: {}",
                                self.policy.budget, q.last_err
                            ),
                        );
                        continue;
                    }
                    est += frame_estimate(&q.req);
                    parts.push(q);
                }
            }
            for p in &mut parts {
                p.attempts += 1;
                if p.attempts > 1 {
                    self.shared.retries.fetch_add(1, Ordering::SeqCst);
                }
            }
            let batch = parts.len() > 1;
            let payload = if batch {
                let items: Vec<WireEvalRequest> = parts
                    .iter()
                    .map(|p| match &p.req {
                        Request::Eval(q) => q.clone(),
                        _ => unreachable!("only evals coalesce"),
                    })
                    .collect();
                Request::EvalBatch(items).encode()
            } else {
                parts[0].req.encode()
            };
            let conn = self.conn.as_mut().unwrap();
            let slots: Vec<Arc<ReplySlot>> =
                parts.iter().map(|p| Arc::clone(&p.slot)).collect();
            // queue the slots before the frame: the server cannot
            // answer an unwritten request, so FIFO order is preserved
            conn.inflight.lock().unwrap().push_back(Written { parts, batch });
            match proto::write_frame(&mut conn.stream, &payload) {
                Ok(()) => {
                    if batch {
                        self.shared.batched_frames.fetch_add(1, Ordering::SeqCst);
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::InvalidInput => {
                    // rejected by the frame size guard before touching
                    // the socket: harmless for the connection.  A
                    // single oversized request is terminal (retrying
                    // cannot shrink it); an oversized *batch* replays
                    // its parts as single frames instead
                    let popped = {
                        let mut g = conn.inflight.lock().unwrap();
                        let ours = g.back().is_some_and(|w| {
                            w.parts
                                .first()
                                .is_some_and(|q| Arc::ptr_eq(&q.slot, &slots[0]))
                        });
                        ours.then(|| g.pop_back()).flatten()
                    };
                    match popped {
                        Some(w) if w.batch => {
                            for mut q in w.parts.into_iter().rev() {
                                q.batch_ok = false;
                                q.last_err = format!("send failed: {e}");
                                self.queue.insert(i, q);
                            }
                        }
                        _ => {
                            for s in &slots {
                                s.fill(Err(format!("send failed: {e}")));
                            }
                        }
                    }
                }
                Err(e) => {
                    // a partial frame may be on the wire: the
                    // connection is unrecoverable; the drain requeues
                    // these requests (attempts already charged)
                    self.kill_conn(&format!("send failed: {e}"));
                    return;
                }
            }
        }
    }

    /// Sleep until the nearest actionable instant: a backoff gate
    /// expiring, a dial window opening, or an in-flight deadline.
    fn next_wakeup(&self) -> Duration {
        let now = Instant::now();
        let mut next: Option<Instant> = None;
        let mut consider = |t: Instant| {
            next = Some(match next {
                Some(n) if n <= t => n,
                _ => t,
            });
        };
        for p in &self.queue {
            consider(p.not_before);
            consider(p.deadline);
        }
        if self.conn.is_none() && !self.queue.is_empty() {
            consider(self.dial_not_before);
        }
        if let Some(c) = &self.conn {
            if let Some(front) = c.inflight.lock().unwrap().front() {
                for p in &front.parts {
                    consider(p.deadline);
                }
            }
        }
        match next {
            Some(t) => t.saturating_duration_since(now).min(Duration::from_secs(5)),
            None => Duration::from_secs(5),
        }
    }

    /// Final drain: every queued and in-flight slot resolves closed.
    fn teardown(&mut self) {
        self.shared.dead.store(true, Ordering::SeqCst);
        if let Some(mut conn) = self.conn.take() {
            let _ = conn.stream.shutdown(Shutdown::Both);
            if let Some(h) = conn.reader.take() {
                let _ = h.join();
            }
            let drained: Vec<Written> = {
                let mut g = conn.inflight.lock().unwrap();
                g.drain(..).collect()
            };
            for w in drained {
                for p in w.parts {
                    p.slot.fill(Err("connection to eval server is closed".into()));
                }
            }
        }
        for p in self.queue.drain(..) {
            p.slot.fill(Err("connection to eval server is closed".into()));
        }
        // late events may still hold pendings (e.g. a Retry in the
        // channel when Shutdown arrived); fail those waiters too
        while let Ok(ev) = self.rx.try_recv() {
            match ev {
                Event::Send(p) | Event::Retry { pending: p, .. } => {
                    p.slot.fill(Err("connection to eval server is closed".into()));
                }
                Event::SendMany(ps) | Event::BatchFailed { parts: ps, .. } => {
                    for p in ps {
                        p.slot
                            .fill(Err("connection to eval server is closed".into()));
                    }
                }
                _ => {}
            }
        }
    }
}

/// Classify a terminal client-side failure into the slot.
fn fail(p: &Pending, msg: &str) {
    p.slot.fill(Err(msg.to_string()));
}

/// Whether a pending request may ride in an `EvalBatch` frame.
fn batchable(p: &Pending) -> bool {
    p.batch_ok && !p.handshake && matches!(p.req, Request::Eval(_))
}

/// Conservative over-estimate of one eval's encoded size, for keeping a
/// coalesced frame far below `MAX_FRAME_LEN` without encoding twice.
fn frame_estimate(req: &Request) -> usize {
    match req {
        Request::Eval(q) => {
            let spec = match &q.spec {
                SpecRef::Name(n) => n.len(),
                SpecRef::Id(_) => 4,
            };
            let scenario = q.scenario.app.len()
                + q.scenario.params.iter().map(|(k, _)| k.len() + 16).sum::<usize>();
            q.dsl.len() + spec + scenario + 64
        }
        _ => 64,
    }
}

/// Fan a batch frame's answer back out to its parts: feedback fills,
/// retryable per-item errors (shedding, mid-batch cap hits) reschedule
/// through the manager, terminal per-item errors classify in place.
fn settle_batch(parts: Vec<Pending>, items: Vec<BatchItem>, tx: &mpsc::Sender<Event>) {
    for (part, item) in parts.into_iter().zip(items) {
        match item {
            BatchItem::Feedback(fb) => {
                part.slot.fill(Ok(Response::Feedback(fb)));
            }
            BatchItem::Error { kind, msg, retry_after_ms } if kind.is_retryable() => {
                let _ = tx.send(Event::Retry {
                    pending: part,
                    hint_ms: retry_after_ms,
                    reason: format!("{kind} error: {msg}"),
                });
            }
            BatchItem::Error { kind, msg, retry_after_ms } => {
                part.slot.fill(Ok(Response::Error { kind, msg, retry_after_ms }));
            }
        }
    }
}

/// Per-connection reader: match responses FIFO against the in-flight
/// deque (one entry per *frame*), hand retryable classified errors back
/// to the manager, and report connection death with a classified
/// reason.
fn reader_loop(
    mut stream: TcpStream,
    inflight: Arc<Mutex<VecDeque<Written>>>,
    tx: mpsc::Sender<Event>,
    epoch: u64,
) {
    let close_msg;
    loop {
        let resp = match proto::read_frame(&mut stream) {
            Ok(Some(payload)) => match Response::decode(&payload) {
                Ok(r) => r,
                Err(e) => {
                    // an undecodable response means the stream can no
                    // longer be trusted frame-for-frame; kill the
                    // connection and let the drain replay everything
                    close_msg = format!("connection to eval server failed: {e}");
                    break;
                }
            },
            Ok(None) => {
                close_msg = "connection to eval server is closed".to_string();
                break;
            }
            Err(e) => {
                close_msg = format!("connection to eval server failed: {e}");
                break;
            }
        };
        let written = inflight.lock().unwrap().pop_front();
        let Some(written) = written else {
            // a frame with no awaiting request: either the server
            // refused us up front (connection-capacity errors are sent
            // before any request — surface that message), it reaped an
            // idle connection (a retryable `Deadline` — redialed on the
            // next send), or the stream is out of sync beyond repair;
            // tear down either way
            close_msg = match resp {
                Response::Error { kind, msg, .. } => {
                    format!("eval server refused the connection ({kind}): {msg}")
                }
                _ => "eval server sent an unsolicited response".to_string(),
            };
            break;
        };
        if written.batch {
            match resp {
                Response::FeedbackBatch(items)
                    if items.len() == written.parts.len() =>
                {
                    settle_batch(written.parts, items, &tx);
                }
                Response::Error { kind, msg, retry_after_ms }
                    if kind.is_retryable() =>
                {
                    // the whole frame failed.  A `Decode` / `Version`
                    // answer means the server predates batch frames
                    // (the unknown-tag rule): fall back to single
                    // frames for good.  Anything else (framing,
                    // whole-connection shedding) just replays.
                    let disable = matches!(
                        kind,
                        ErrorKind::Decode | ErrorKind::Version
                    );
                    let _ = tx.send(Event::BatchFailed {
                        parts: written.parts,
                        hint_ms: retry_after_ms,
                        reason: format!("{kind} error: {msg}"),
                        disable_batching: disable,
                    });
                }
                Response::Error { kind, msg, .. } => {
                    for part in written.parts {
                        part.slot.fill(Ok(Response::Error {
                            kind,
                            msg: msg.clone(),
                            retry_after_ms: 0,
                        }));
                    }
                }
                other => {
                    // a batch answered with the wrong shape (length
                    // mismatch or a non-batch variant): FIFO alignment
                    // is gone — requeue the parts and sever
                    let _ = tx.send(Event::BatchFailed {
                        parts: written.parts,
                        hint_ms: 0,
                        reason: format!(
                            "batch answered with {}",
                            other.kind_name()
                        ),
                        disable_batching: false,
                    });
                    close_msg =
                        "eval server misanswered a batch frame".to_string();
                    break;
                }
            }
            continue;
        }
        let pending = written
            .parts
            .into_iter()
            .next()
            .expect("a non-batch frame carries exactly one request");
        if pending.handshake {
            let (ok, msg) = match &resp {
                Response::Pong => (true, String::new()),
                Response::Error { kind, msg, .. } => (
                    false,
                    format!("eval server refused the connection ({kind}): {msg}"),
                ),
                other => (
                    false,
                    format!(
                        "Remote protocol error: expected feedback, got {}",
                        other.kind_name()
                    ),
                ),
            };
            let _ = tx.send(Event::HandshakeDone { epoch, ok, msg });
            continue;
        }
        match resp {
            Response::Error { kind, msg, retry_after_ms } if kind.is_retryable() => {
                // retryable classification (shedding, framing,
                // corruption, version skew): reschedule instead of
                // surfacing — the manager applies backoff and budget
                let _ = tx.send(Event::Retry {
                    pending,
                    hint_ms: retry_after_ms,
                    reason: format!("{kind} error: {msg}"),
                });
            }
            resp => pending.slot.fill(Ok(resp)),
        }
    }
    let _ = stream.shutdown(Shutdown::Both);
    let _ = tx.send(Event::ConnDead { epoch, msg: close_msg });
}
