//! Cross-process serving (the PR 5 wire layer): a versioned binary
//! protocol, a threaded TCP server, and a remote client — so
//! optimization campaigns can live in *other processes* (or other
//! machines) and hammer one shared, warm-cached
//! [`EvalService`](crate::coordinator::EvalService).
//!
//! Zero external dependencies: framing and the codec are hand-rolled
//! over `std::net` / `std::io`, like the rest of the crate's
//! clap/criterion/proptest stand-ins.
//!
//! # Frame format
//!
//! Every message travels in one length-prefixed frame:
//!
//! ```text
//! +----------------+------------------------------------------+
//! | len: u32 LE    | payload (len bytes)                      |
//! +----------------+------------------------------------------+
//!                   payload = [version: u8][tag: u8][body...]
//! ```
//!
//! * `len` counts the payload only (version byte included) and must be
//!   in `1..=MAX_FRAME`; a length outside that range is an
//!   unrecoverable framing error — the server answers a classified
//!   [`proto::ErrorKind::Frame`] response and closes, since the stream
//!   cannot be resynchronized.
//! * The **version byte** ([`proto::WIRE_VERSION`]) leads every
//!   payload, *outside* the versioned body, so any future version can
//!   still be skipped frame-by-frame: a version-skewed frame is
//!   answered with a classified [`proto::ErrorKind::Version`] response
//!   and the connection keeps serving.
//! * `tag` selects the [`proto::Request`] / [`proto::Response`]
//!   variant; bodies are fixed-layout little-endian fields with
//!   `u32`-length-prefixed UTF-8 strings, `u64`-bit `f64`s, and
//!   `0/1` booleans.  Decoding is total: truncated, trailing,
//!   non-UTF-8, or unknown-tag payloads produce
//!   [`proto::DecodeError`]s, never panics — answered as classified
//!   [`proto::ErrorKind::Decode`] responses, never connection aborts.
//!
//! # Pipelining
//!
//! Responses are delivered strictly in request order per connection, so
//! a client may keep many requests in flight on one socket (the
//! [`client::RemoteEvalClient`] reader thread matches responses FIFO,
//! and the [`server::EvalServer`] per-connection writer resolves
//! [`EvalTicket`](crate::coordinator::EvalTicket)s in arrival order
//! while the evaluations themselves proceed concurrently on the
//! service's worker pool).

pub mod client;
pub mod proto;
pub mod server;

pub use client::{RemoteEvalClient, RemoteTicket};
pub use proto::{Scenario, SpecRef, WIRE_VERSION};
pub use server::EvalServer;
