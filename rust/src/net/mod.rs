//! Cross-process serving (the PR 5 wire layer, hardened in PR 7,
//! multiplexed in PR 8): a versioned binary protocol with batch frames,
//! a fixed-pool multiplexed TCP server, a fault-tolerant batching
//! client, a deterministic chaos proxy, and a synthetic-client loadtest
//! harness — so optimization campaigns can live in *other processes*
//! (or other machines) and hammer one shared, warm-cached
//! [`EvalService`](crate::coordinator::EvalService), even over a wire
//! that drops, delays, corrupts, or truncates.
//!
//! Zero external dependencies: framing, the codec, and the readiness
//! loops are hand-rolled over `std::net` / `std::io`, like the rest of
//! the crate's clap/criterion/proptest stand-ins.
//!
//! # Frame format
//!
//! Every message travels in one length-prefixed, checksummed frame:
//!
//! ```text
//! +-------------+--------------------------------+---------------+
//! | len: u32 LE | payload (len bytes)            | crc: u32 LE   |
//! +-------------+--------------------------------+---------------+
//!                payload = [version: u8][tag: u8][body...]
//! ```
//!
//! * `len` counts the payload only (version byte included) and must be
//!   in `1..=`[`proto::MAX_FRAME_LEN`]; a length outside that range —
//!   including a hostile multi-gigabyte claim, which is rejected
//!   *before* any allocation — is an unrecoverable framing error: the
//!   server answers a classified [`proto::ErrorKind::Frame`] response
//!   and closes, since the stream cannot be resynchronized.
//! * `crc` is a FNV-1a-folded checksum of the payload; a mismatch
//!   (bytes corrupted in transit) is likewise answered as a classified
//!   `Frame` error and the connection closed — a corrupted request is
//!   *never* executed, and the client's retry machinery replays it on a
//!   fresh connection.
//! * The **version byte** ([`proto::WIRE_VERSION`]) leads every
//!   payload, *outside* the versioned body, so any future version can
//!   still be skipped frame-by-frame: a version-skewed frame is
//!   answered with a classified [`proto::ErrorKind::Version`] response
//!   and the connection keeps serving.
//! * `tag` selects the [`proto::Request`] / [`proto::Response`]
//!   variant; bodies are fixed-layout little-endian fields with
//!   `u32`-length-prefixed UTF-8 strings, `u64`-bit `f64`s, and
//!   `0/1` booleans.  Decoding is total: truncated, trailing,
//!   non-UTF-8, or unknown-tag payloads produce
//!   [`proto::DecodeError`]s, never panics — answered as classified
//!   [`proto::ErrorKind::Decode`] responses, never connection aborts.
//! * Servers parse incrementally with [`proto::frame_step`], so a
//!   frame arriving in arbitrary fragments never blocks an I/O thread.
//!
//! # Batch frames
//!
//! [`proto::Request::EvalBatch`] / [`proto::Response::FeedbackBatch`]
//! carry up to [`proto::MAX_BATCH_ITEMS`] evaluations per frame — one
//! syscall round-trip for a whole proposal batch.  Items are admitted,
//! shed, and answered *individually* (a [`proto::BatchItem`] each), so
//! a bad or shed item never poisons its batch-mates, and results are
//! bit-identical to frame-per-eval submission.  The tags are new:
//! pre-batch decoders classify them as retryable `Decode` errors per
//! the unknown-tag rule, which the client uses to fall back to single
//! frames automatically ([`client`] module docs).
//!
//! # Error taxonomy
//!
//! Every wire failure is classified by [`proto::ErrorKind`], and the
//! class decides who acts and how:
//!
//! | kind         | meaning                          | retryable? |
//! |--------------|----------------------------------|------------|
//! | `Frame`      | unframeable stream / bad checksum| yes — replay on a fresh connection |
//! | `Version`    | wire version skew                | yes — a fleet mid-upgrade converges |
//! | `Decode`     | undecodable payload              | yes — usually corruption that slipped framing |
//! | `Overloaded` | request shed under load          | yes — after the `retry_after_ms` hint |
//! | `Deadline`   | connection reaped while idle     | yes — reconnect and resume |
//! | `BadRequest` | the request itself is invalid    | **no** — retrying cannot fix it |
//! | `Internal`   | server-side invariant failure    | **no** — retrying hides bugs |
//!
//! *Retryable* ([`proto::ErrorKind::is_retryable`]) means the same
//! request may legitimately succeed if re-sent; the
//! [`client::RetryPolicy`] machinery requeues those transparently with
//! bounded, seeded-jitter backoff until its budget or per-request
//! deadline runs out, and only then surfaces a classified
//! `Remote ... error` execution error.  Terminal kinds surface
//! immediately.  `Overloaded` responses carry a `retry_after_ms` hint —
//! the server's estimate of when queue pressure will clear, scaled by
//! backlog depth — which the client honors as a backoff floor.
//!
//! # The multiplexed server
//!
//! [`server::EvalServer`] drives all connections from a small fixed
//! pool of I/O threads over nonblocking sockets ([`server`] module docs
//! have the full slab lifecycle).  Connection cost is a slab entry, not
//! two OS threads, so thousands of concurrent campaign clients are
//! routine; [`loadtest`] is the harness that proves it.  Sizing knobs,
//! all env-tunable: `MAPPEROPT_IO_THREADS` (pool size, default
//! `min(4, cores)`), `MAPPEROPT_MAX_CONNECTIONS` (connection cap,
//! default 4096, refusals counted and classified),
//! `MAPPEROPT_CONN_DEADLINE_S` (idle reap, answered as retryable
//! `Deadline`).
//!
//! # Fault tolerance
//!
//! The server protects itself (queue high-water shedding, per-
//! connection in-flight caps, counted connection-capacity refusals,
//! idle-connection reaping, graceful drain — see [`server`]); the
//! client hides transient failure (reconnect and replay, budgets,
//! deadlines, batch fallback — see [`client`]); and [`chaos`] proves
//! the combination: a seeded in-process TCP proxy injects delays,
//! resets, truncation, corruption, and blackholes on a deterministic
//! byte-offset schedule, and the `chaos-smoke` driver asserts a
//! campaign run through it is *bit-identical* to a clean local run.
//!
//! # Pipelining
//!
//! Responses are delivered strictly in request order per connection, so
//! a client may keep many requests in flight on one socket (the
//! [`client::RemoteEvalClient`] reader thread matches response frames
//! FIFO, and the server's per-connection reply FIFO resolves
//! [`EvalTicket`](crate::coordinator::EvalTicket)s in arrival order
//! while the evaluations themselves proceed concurrently on the
//! service's worker pool).
//!
//! # The sharded fleet (PR 9)
//!
//! [`router::EvalRouter`] fronts N `EvalServer` shards behind one
//! address speaking the *same* wire protocol, so a campaign scales
//! past one server without clients changing a line:
//!
//! * **Cache-affinity routing.** Each eval's semantic identity (spec
//!   ref, scenario, DSL, mode — *not* priority) is hashed with the
//!   shared FNV-1a primitive ([`router::affinity_key`]) onto a
//!   consistent-hash ring ([`router::HashRing`],
//!   [`router::RING_VNODES`] virtual nodes per shard).  The eval cache
//!   key and the routing key bind the same fields, so identical and
//!   re-submitted mappers always land on the shard already warm for
//!   them — fleet-aggregate hit rates stay within a few points of a
//!   single server's — and a membership change moves ~1/N of the
//!   keyspace, never a full reshuffle.
//! * **Replicated registries.** `RegisterSpec` fans out to every live
//!   shard and answers only on unanimous ack;
//!   [`router::EvalRouter::join_shard`] replays the acked log into a
//!   joiner before it takes traffic.  Spec *ids* stay aligned because
//!   shards preregister built-ins in the same order and router-mediated
//!   registrations apply fleet-wide; concurrent registrations racing on
//!   different front connections could still skew ids — clients that
//!   must survive that pin [`SpecRef::Name`] refs.
//! * **Membership & failover.** Shards are `up` / `draining` / `dead`
//!   ([`crate::coordinator::ShardSnapshot`] states).
//!   [`router::EvalRouter::leave_shard`] drains gracefully (no new
//!   work, in-flight settles).  A severed backend link answers its
//!   in-flight requests with *retryable* `Overloaded` errors, so the
//!   client's existing [`client::RetryPolicy`] replays them onto the
//!   rebuilt ring — failover rides the same path as overload and
//!   chaos, and purity keeps the replayed answers bit-identical.
//! * **Fleet observability.** `Stats` aggregates per-shard snapshots
//!   ([`StatsSnapshot::aggregate_fleet`](crate::coordinator::StatsSnapshot::aggregate_fleet)):
//!   counters sum, and per-shard rates travel in the snapshot's fleet
//!   tail under the zero-fill decode rule (older payloads decode with
//!   an empty shard list).  `Summary` concatenates per-shard blocks.
//!
//! Capacity note: each shard is reached through
//! `io_threads x BACKEND_LANES` router connections, each subject to the
//! server's per-connection in-flight cap — the funnel bound is
//! `io_threads * 4 *` [`server::MAX_CONN_IN_FLIGHT`] concurrent evals
//! per shard, far above what the loadtest needs.
//!
//! # Telemetry & tracing (PR 10)
//!
//! The [`crate::obs`] layer threads through every serving hop —
//! always-on histograms, opt-in tracing, failure-window forensics —
//! without perturbing a single score:
//!
//! * **Stage histograms.** Every layer records its pipeline stages
//!   into lock-cheap log2-bucket histograms
//!   ([`crate::obs::Hist`], one relaxed `fetch_add` per sample):
//!   the client its submit→reply wall time (`client`), the router its
//!   routing decision (`route`) and backend round-trip (`upstream`),
//!   the server its admission and reply-write work (`admit`/`write`),
//!   and the service its queue wait, cache paths, decision resolve,
//!   and simulation (`queue`/`hit`/`decision`/`splice`/`cold`/
//!   `resolve`/`sim`).  Snapshots ride the `Stats` payload as a
//!   trailing histogram section under the same zero-fill decode rule
//!   as the fleet tail — old peers truncate it cleanly, and
//!   single-server histogram-free snapshots stay byte-identical with
//!   older encoders.  Fleet aggregation merges bucket-wise (exact:
//!   merging per-shard histograms equals histogramming the
//!   concatenated samples), and `mapperopt top --remote ADDR` renders
//!   the live per-stage breakdown.
//! * **Request tracing.** A client with tracing on (`--trace` /
//!   `MAPPEROPT_TRACE`) stamps each eval with a nonzero trace id
//!   carried as a trailing optional wire field — untraced traffic
//!   stays byte-identical to the pre-trace wire, and the id is
//!   provably inert (it is outside the affinity key and every cache
//!   key).  Traced replies carry a per-eval
//!   [`crate::obs::EvalTelemetry`] rider
//!   (`{queue_ns, cache_path, sim_ns}`) into
//!   [`SystemFeedback`](crate::feedback::SystemFeedback), and the
//!   serving side records a per-request span
//!   ([`crate::obs::SpanRecord`]) of stage start/duration pairs.
//! * **The flight recorder.** Each process keeps a bounded ring
//!   ([`crate::obs::FlightRecorder`], `MAPPEROPT_TRACE_RING` spans) of
//!   the spans worth keeping: traced requests, every error/shed, and
//!   untraced requests slower than `MAPPEROPT_TRACE_SLOW_MS`.
//!   [`proto::Request::TraceDump`] fetches it over the wire — the
//!   router fans the dump out and concatenates shard spans ahead of
//!   its own — and the smoke drivers print it automatically on
//!   failure, so a red CI run carries its own forensics.

pub mod chaos;
pub mod client;
pub mod loadtest;
pub mod proto;
pub mod router;
pub mod server;

pub use chaos::{ChaosConfig, ChaosProxy, ChaosStats};
pub use client::{RemoteEvalClient, RemoteTicket, RetryPolicy};
pub use loadtest::{LoadtestConfig, LoadtestReport};
pub use proto::{Scenario, SpecRef, WireEvalRequest, WIRE_VERSION};
pub use router::{affinity_key, EvalRouter, HashRing, RING_VNODES};
pub use server::{EvalServer, ServerConfig};
