//! A deterministic in-process chaos proxy: a std-only TCP forwarder
//! that injects faults — delays, byte corruption, truncation,
//! connection resets, blackholes — on a schedule that is a pure
//! function of `(seed, connection index, direction)`, using the same
//! seeded [`Rng`](crate::util::rng::Rng) as the rest of the tree.
//!
//! Faults are scheduled by *cumulative byte offset*, not by read call:
//! each direction forwards exactly `gap` bytes (drawn from the seeded
//! RNG), applies one fault, draws the next gap, and so on — so the
//! schedule does not depend on how TCP happens to chunk the stream, and
//! a test that replays a seed replays the same faults at the same
//! stream positions.  [`ChaosConfig::max_faults_per_conn`] bounds the
//! faults per connection-direction, after which the connection runs
//! clean — together with the client's retry budget this guarantees
//! forward progress.
//!
//! The proxy front stays bound across backend restarts
//! ([`ChaosProxy::set_backend`]), which is how the fault-injection
//! tests give a reconnecting client a stable address while the real
//! server is killed and rebound elsewhere.
//!
//! What each fault exercises:
//!
//! * **Delay** — latency spikes; retry deadlines and backoff.
//! * **Corrupt** (XOR one forwarded byte) — the frame checksum: the
//!   receiver classifies a checksum mismatch, answers a retryable
//!   `Frame` error, and the request is retried, never mis-executed.
//! * **Truncate** (swallow a few bytes, then cut) — mid-frame
//!   connection loss; reconnect-and-replay.
//! * **Reset** — abrupt connection death between frames.
//! * **Blackhole** (swallow everything, answer nothing) — a hung peer;
//!   only the client's per-request deadline can save it, so enable this
//!   one with a short deadline.

use std::io::{Read, Write};
use std::net::{
    IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream,
    ToSocketAddrs,
};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use crate::util::rng::Rng;

/// Fault mix and schedule parameters; see the module docs for what
/// each fault kind exercises.  Weights of 0 disable a kind.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    /// Master seed; every connection-direction forks its own stream
    /// from this, so one seed fixes the entire fault schedule.
    pub seed: u64,
    /// Bytes forwarded cleanly between faults, drawn uniformly from
    /// `gap.0..=gap.1` per fault.
    pub gap: (usize, usize),
    /// Injected delay duration, drawn uniformly from
    /// `delay_ms.0..=delay_ms.1`.
    pub delay_ms: (u64, u64),
    pub delay_weight: u32,
    pub corrupt_weight: u32,
    pub truncate_weight: u32,
    pub reset_weight: u32,
    pub blackhole_weight: u32,
    /// Faults per connection-direction before it runs clean; the
    /// progress guarantee (a retried connection eventually gets
    /// through).
    pub max_faults_per_conn: u32,
}

impl Default for ChaosConfig {
    /// The chaos-smoke mix: delays, corruption, truncation, and resets
    /// on, blackholes off (they are only survivable with a short
    /// per-request deadline — opt in deliberately).
    fn default() -> Self {
        ChaosConfig {
            seed: 0xC4A0_5EED,
            gap: (192, 4096),
            delay_ms: (1, 15),
            delay_weight: 3,
            corrupt_weight: 2,
            truncate_weight: 1,
            reset_weight: 1,
            blackhole_weight: 0,
            max_faults_per_conn: 2,
        }
    }
}

impl ChaosConfig {
    fn weight_total(&self) -> u32 {
        self.delay_weight
            + self.corrupt_weight
            + self.truncate_weight
            + self.reset_weight
            + self.blackhole_weight
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Fault {
    Delay,
    Corrupt,
    Truncate,
    Reset,
    Blackhole,
}

/// Injected-fault tallies (monotonic; read with [`ChaosProxy::stats`]).
#[derive(Default)]
struct Tallies {
    connections: AtomicU64,
    delays: AtomicU64,
    corruptions: AtomicU64,
    truncations: AtomicU64,
    resets: AtomicU64,
    blackholes: AtomicU64,
}

/// A point-in-time copy of the proxy's fault counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChaosStats {
    pub connections: u64,
    pub delays: u64,
    pub corruptions: u64,
    pub truncations: u64,
    pub resets: u64,
    pub blackholes: u64,
}

impl ChaosStats {
    /// Total faults injected (connections are not faults).
    pub fn faults(&self) -> u64 {
        self.delays
            + self.corruptions
            + self.truncations
            + self.resets
            + self.blackholes
    }
}

/// The deterministic fault schedule of one connection-direction.
struct Schedule {
    rng: Rng,
    cfg: ChaosConfig,
    /// Faults left before this direction runs clean.
    remaining: u32,
    /// Clean bytes to forward before the next fault fires.
    until_next: usize,
}

impl Schedule {
    /// `conn` is the proxy-wide connection index, `dir` 0 for
    /// client-to-backend and 1 for backend-to-client — the only inputs
    /// besides the seed, so equal seeds replay equal schedules.
    fn new(cfg: &ChaosConfig, conn: u64, dir: u64) -> Schedule {
        let mut rng =
            Rng::new(cfg.seed ^ conn.wrapping_mul(0x9E37_79B9).wrapping_add(dir));
        let until_next = draw_gap(&mut rng, cfg.gap);
        Schedule {
            rng,
            cfg: cfg.clone(),
            remaining: cfg.max_faults_per_conn,
            until_next,
        }
    }

    fn armed(&self) -> bool {
        self.remaining > 0 && self.cfg.weight_total() > 0
    }

    /// Weighted draw of the next fault kind; also consumes one of the
    /// per-connection fault slots and re-arms the byte gap.
    fn draw_fault(&mut self) -> Fault {
        let mut r = self.rng.below(self.cfg.weight_total() as usize) as u32;
        let fault = [
            (Fault::Delay, self.cfg.delay_weight),
            (Fault::Corrupt, self.cfg.corrupt_weight),
            (Fault::Truncate, self.cfg.truncate_weight),
            (Fault::Reset, self.cfg.reset_weight),
            (Fault::Blackhole, self.cfg.blackhole_weight),
        ]
        .into_iter()
        .find_map(|(f, w)| {
            if r < w {
                Some(f)
            } else {
                r -= w;
                None
            }
        })
        .unwrap_or(Fault::Delay);
        self.remaining -= 1;
        self.until_next = draw_gap(&mut self.rng, self.cfg.gap);
        fault
    }
}

fn draw_gap(rng: &mut Rng, (lo, hi): (usize, usize)) -> usize {
    let lo = lo.max(1);
    let hi = hi.max(lo);
    lo + rng.below(hi - lo + 1)
}

/// A seeded fault-injecting TCP proxy in front of one backend (see
/// module docs).
pub struct ChaosProxy {
    addr: SocketAddr,
    backend: Arc<Mutex<SocketAddr>>,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    tallies: Arc<Tallies>,
}

impl ChaosProxy {
    /// Bind the front at `front` (use `"127.0.0.1:0"` for an ephemeral
    /// port) forwarding to `backend`, with faults drawn from `cfg`.
    pub fn bind<A: ToSocketAddrs>(
        front: &str,
        backend: A,
        cfg: ChaosConfig,
    ) -> std::io::Result<ChaosProxy> {
        let backend = backend
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::Other,
                    "backend address resolves to nothing",
                )
            })?;
        let listener = TcpListener::bind(front)?;
        let addr = listener.local_addr()?;
        let backend = Arc::new(Mutex::new(backend));
        let stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(Mutex::new(Vec::new()));
        let tallies = Arc::new(Tallies::default());
        let (b, s, c, t) = (
            Arc::clone(&backend),
            Arc::clone(&stop),
            Arc::clone(&conns),
            Arc::clone(&tallies),
        );
        let accept = thread::Builder::new()
            .name("chaos-accept".into())
            .spawn(move || {
                let mut conn_id: u64 = 0;
                for incoming in listener.incoming() {
                    if s.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(client) = incoming else { continue };
                    let target = *b.lock().unwrap();
                    // an unreachable backend looks like a refused/cut
                    // connection to the client — exactly the failure a
                    // killed server produces
                    let Ok(server) = TcpStream::connect(target) else {
                        let _ = client.shutdown(Shutdown::Both);
                        continue;
                    };
                    let _ = client.set_nodelay(true);
                    let _ = server.set_nodelay(true);
                    t.connections.fetch_add(1, Ordering::SeqCst);
                    spawn_pumps(client, server, conn_id, &cfg, &c, &t);
                    conn_id += 1;
                }
            })?;
        Ok(ChaosProxy {
            addr,
            backend,
            stop,
            accept: Some(accept),
            conns,
            tallies,
        })
    }

    /// The stable front address clients connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Repoint the proxy at a new backend (e.g. a restarted server on a
    /// fresh port); existing connections keep their old backend until
    /// they die.
    pub fn set_backend(&self, backend: SocketAddr) {
        *self.backend.lock().unwrap() = backend;
    }

    /// Injected-fault counters so far.
    pub fn stats(&self) -> ChaosStats {
        ChaosStats {
            connections: self.tallies.connections.load(Ordering::SeqCst),
            delays: self.tallies.delays.load(Ordering::SeqCst),
            corruptions: self.tallies.corruptions.load(Ordering::SeqCst),
            truncations: self.tallies.truncations.load(Ordering::SeqCst),
            resets: self.tallies.resets.load(Ordering::SeqCst),
            blackholes: self.tallies.blackholes.load(Ordering::SeqCst),
        }
    }

    /// Stop accepting and sever every proxied connection.
    pub fn shutdown(mut self) {
        self.teardown();
    }

    fn teardown(&mut self) {
        if let Some(h) = self.accept.take() {
            self.stop.store(true, Ordering::SeqCst);
            // wake the blocking accept (loopback-aim wildcard binds)
            let mut target = self.addr;
            if target.ip().is_unspecified() {
                let loopback = match target.ip() {
                    IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                    IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
                };
                target.set_ip(loopback);
            }
            let _ = TcpStream::connect(target);
            let _ = h.join();
        }
        let streams: Vec<TcpStream> =
            self.conns.lock().unwrap().drain(..).collect();
        for s in streams {
            let _ = s.shutdown(Shutdown::Both);
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.teardown();
    }
}

/// Spawn the two forwarding pumps of one proxied connection, each with
/// its own deterministic schedule.
fn spawn_pumps(
    client: TcpStream,
    server: TcpStream,
    conn_id: u64,
    cfg: &ChaosConfig,
    conns: &Arc<Mutex<Vec<TcpStream>>>,
    tallies: &Arc<Tallies>,
) {
    {
        let mut g = conns.lock().unwrap();
        if let Ok(c) = client.try_clone() {
            g.push(c);
        }
        if let Ok(s) = server.try_clone() {
            g.push(s);
        }
        // stale handles accumulate one pair per connection; keep the
        // registry from growing without bound in long sweeps
        if g.len() > 1024 {
            g.drain(..g.len() - 1024);
        }
    }
    let up = (client.try_clone(), server.try_clone());
    if let (Ok(from), Ok(to)) = up {
        let sched = Schedule::new(cfg, conn_id, 0);
        let t = Arc::clone(tallies);
        let _ = thread::Builder::new()
            .name("chaos-up".into())
            .spawn(move || pump(from, to, sched, t));
    }
    let sched = Schedule::new(cfg, conn_id, 1);
    let t = Arc::clone(tallies);
    let _ = thread::Builder::new()
        .name("chaos-down".into())
        .spawn(move || pump(server, client, sched, t));
}

/// Forward one direction, injecting the schedule's faults at their
/// exact byte offsets.  Returning severs both streams (the pump owns
/// clones of both sockets), so a fault that cuts one direction cuts the
/// connection — half-open proxied connections are not a state the wire
/// protocol can use anyway.
fn pump(
    mut from: TcpStream,
    mut to: TcpStream,
    mut sched: Schedule,
    tallies: Arc<Tallies>,
) {
    let mut buf = [0u8; 8192];
    loop {
        if sched.armed() && sched.until_next == 0 {
            match sched.draw_fault() {
                Fault::Delay => {
                    let (lo, hi) = sched.cfg.delay_ms;
                    let hi = hi.max(lo);
                    let ms = lo + sched.rng.below((hi - lo + 1) as usize) as u64;
                    tallies.delays.fetch_add(1, Ordering::SeqCst);
                    thread::sleep(Duration::from_millis(ms));
                    continue;
                }
                Fault::Corrupt => {
                    // XOR the next forwarded byte with a nonzero mask:
                    // the payload checksum catches it downstream
                    let mut b = [0u8; 1];
                    match from.read(&mut b) {
                        Ok(1) => {}
                        _ => break,
                    }
                    b[0] ^= (1 + sched.rng.below(255)) as u8;
                    tallies.corruptions.fetch_add(1, Ordering::SeqCst);
                    if to.write_all(&b).is_err() {
                        break;
                    }
                    continue;
                }
                Fault::Truncate => {
                    // swallow a few bytes mid-stream, then cut: the
                    // peer sees a frame that ends early
                    let n = 1 + sched.rng.below(64);
                    let mut sink = [0u8; 64];
                    let _ = from.read(&mut sink[..n]);
                    tallies.truncations.fetch_add(1, Ordering::SeqCst);
                    break;
                }
                Fault::Reset => {
                    tallies.resets.fetch_add(1, Ordering::SeqCst);
                    break;
                }
                Fault::Blackhole => {
                    // swallow everything and answer nothing: only the
                    // client's per-request deadline gets it out
                    tallies.blackholes.fetch_add(1, Ordering::SeqCst);
                    let mut sink = [0u8; 8192];
                    while matches!(from.read(&mut sink), Ok(n) if n > 0) {}
                    break;
                }
            }
        }
        let take = if sched.armed() {
            buf.len().min(sched.until_next)
        } else {
            buf.len()
        };
        let n = match from.read(&mut buf[..take]) {
            Ok(0) | Err(_) => break,
            Ok(n) => n,
        };
        if to.write_all(&buf[..n]).is_err() {
            break;
        }
        if sched.armed() {
            sched.until_next -= n;
        }
    }
    let _ = from.shutdown(Shutdown::Both);
    let _ = to.shutdown(Shutdown::Both);
}
