//! The multiplexed TCP eval server: a small fixed pool of I/O threads
//! drives thousands of nonblocking connections into one shared
//! [`EvalService`], so remote clients hit the same feedback / plan /
//! policy / decision caches and in-flight deduplication as local ones —
//! at O(pool) threads instead of the old O(2·connections).
//!
//! # Architecture
//!
//! One **acceptor** thread blocks on `accept`.  Each accepted stream is
//! made nonblocking and handed round-robin to one of
//! [`ServerConfig::io_threads`] **I/O threads** (env
//! `MAPPEROPT_IO_THREADS`; default `min(4, cores)`).  An I/O thread
//! owns a *slab* of per-connection state ([`ConnState`]): free slots
//! are recycled through a free list, so slot indices are stable while a
//! connection lives and O(1) to reuse when it dies.  Per connection the
//! slab holds:
//!
//! * an **incremental frame decoder** — bytes accumulate in a read
//!   buffer and [`proto::frame_step`] peels off whole frames as they
//!   complete; a partial frame never blocks the thread, it just waits
//!   for more bytes;
//! * a **pending-reply FIFO** — synchronous requests resolve to
//!   [`Reply::Now`] immediately, evaluations become
//!   [`EvalTicket`]s admitted via the non-blocking
//!   [`EvalService::try_submit`](crate::coordinator::EvalService::try_submit),
//!   and batch frames become one [`Reply::Batch`] of per-item slots.
//!   The FIFO head is polled each scan; replies encode strictly in
//!   request order (the client matches FIFO) while the evaluations
//!   themselves run concurrently on the service's worker pool;
//! * an **in-flight count** whose accounting is a drop-guard *owned by
//!   the reply entry* ([`InFlightGuard`]): any teardown path that drops
//!   a queued reply — write error, reap, kill — releases its units, so
//!   a recycled slab slot always starts at zero;
//! * an **idle deadline** (`last_read` / write-progress stamps) driving
//!   the reaping rules below.
//!
//! The readiness loop is std-only: each scan try-reads, resolves ready
//! replies, and try-writes every live connection; when a full scan
//! makes no progress the thread backs off adaptively (yield, then
//! microsleeps capped at 500µs) so an idle server costs ~nothing and a
//! busy one never sleeps.
//!
//! # Batch frames
//!
//! [`Request::EvalBatch`] carries up to
//! [`proto::MAX_BATCH_ITEMS`](super::proto::MAX_BATCH_ITEMS) mappers in
//! one frame; the server admits each item independently (per-item
//! shedding, per-item bad-request classification) and answers one
//! [`Response::FeedbackBatch`] of equal length once every item
//! resolves.  One syscall round-trip per proposal batch instead of K.
//!
//! # Self-protection
//!
//! The serving path never queues or blocks without bound:
//!
//! * **Queue high-water shedding** — at the service's high-water mark,
//!   lowest-priority work is shed with a classified
//!   [`ErrorKind::Overloaded`] response carrying a retry-after hint
//!   (see [`CacheConfig::queue_high_water`]).
//! * **Per-connection in-flight cap** — a connection may keep at most
//!   [`MAX_CONN_IN_FLIGHT`] evaluations pending; excess submissions
//!   (batch items included) are answered `Overloaded` immediately and
//!   counted as shed.
//! * **Connection capacity** — beyond
//!   [`ServerConfig::max_connections`] concurrent connections (env
//!   `MAPPEROPT_MAX_CONNECTIONS`, default 4096) the acceptor answers a
//!   classified `Overloaded` refusal, **counts it** in
//!   [`ServiceStats::refused_connections`](crate::coordinator::ServiceStats),
//!   and closes the refused stream explicitly — refusals are visible in
//!   `Stats` and never leak a half-open socket.
//! * **Idle/read deadline** — a connection with nothing pending that
//!   sends no bytes for `MAPPEROPT_CONN_DEADLINE_S` seconds (default
//!   300; `0` disables) is reaped: counted in
//!   [`ServiceStats::reaped_connections`](crate::coordinator::ServiceStats),
//!   answered with a *retryable* [`ErrorKind::Deadline`] error, and
//!   closed — a reconnecting client resumes transparently.  A
//!   connection that stops draining its replies (write backlog with no
//!   socket progress for the same deadline) is reaped hard; one with
//!   evaluations still in flight is never reaped, however slow the
//!   eval.
//! * **Write backlog bound** — while a connection holds more than
//!   [`MAX_WRITE_BACKLOG`] encoded-but-unsent bytes, the server stops
//!   reading from it (natural TCP backpressure) instead of buffering
//!   without bound.
//! * **Graceful drain** — [`EvalServer::shutdown`] stops accepting,
//!   stops reading new requests, answers everything already in flight,
//!   flushes, and joins the I/O pool, so restarts never strand a
//!   pending reply.  [`EvalServer::kill`] severs every connection
//!   abruptly instead (what the fault-injection tests use to simulate a
//!   crash).
//!
//! Fault containment: framing errors (including checksum mismatches),
//! version skew, undecodable payloads, unknown specs/apps, and worker
//! panics are all answered as classified responses
//! ([`proto::Response::Error`] or error-carrying feedback), never
//! connection aborts — the only hard close is an unrecoverable frame,
//! answered first.
//!
//! [`CacheConfig::queue_high_water`]: crate::coordinator::CacheConfig

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{
    IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream,
};
use std::sync::atomic::{AtomicBool, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::apps;
use crate::coordinator::{EvalRequest, EvalService, EvalTicket};
use crate::feedback::SystemFeedback;
use crate::obs::Stage;

use super::proto::{
    self, BatchItem, ErrorKind, FrameStep, Request, Response, SpecRef,
    WireEvalRequest,
};

/// Per-request budget on the simulated task graph a remote scenario may
/// ask for: `apps::scenario`'s per-parameter bounds keep the arithmetic
/// sane, but a product of in-range extents can still describe a graph
/// whose materialization would exhaust memory — and an allocation
/// failure *aborts* the shared server (it does not unwind into the
/// worker-panic containment).  Oversized scenarios classify as bad
/// requests instead.
const MAX_REQUEST_POINT_TASKS: i64 = 1 << 24;

/// Registered machine specs are deduplicated by fingerprint but the
/// registry itself is append-only (ids must stay stable), so remote
/// registration is capped — the one piece of service state a client
/// could otherwise grow without bound.
const MAX_REGISTERED_SPECS: usize = 1024;

/// Registry entries live forever and their names are re-cloned by every
/// summary/stats request, so a registered name (the alias *and* the
/// name embedded in the spec) may not exceed this — otherwise the entry
/// cap above still admits gigabytes of hostile name bytes.
const MAX_SPEC_NAME_BYTES: usize = 256;

/// Default [`ServerConfig::max_connections`].  A connection now costs a
/// slab entry and a socket, not two OS threads, so the cap exists to
/// bound fds/memory under a reconnect storm — not thread count — and
/// sits far above the old thread-per-connection limit of 256.
pub const DEFAULT_MAX_CONNECTIONS: usize = 4096;

/// Evaluations one connection may keep pending at once; submissions
/// past the cap are answered [`ErrorKind::Overloaded`] immediately
/// (counted as shed), so a single pipelining client cannot build an
/// unbounded ticket backlog on its reply FIFO.
pub const MAX_CONN_IN_FLIGHT: usize = 64;

/// Replies (of any kind) one connection may have queued before the
/// server stops *parsing* its buffered bytes — a second backpressure
/// layer behind the in-flight cap, bounding FIFO growth from
/// zero-cost requests (pings, stats) pipelined faster than the socket
/// drains.
pub(crate) const MAX_PENDING_REPLIES: usize = 2 * MAX_CONN_IN_FLIGHT;

/// Encoded-but-unsent reply bytes beyond which the server stops
/// reading from a connection until its socket drains (see module
/// docs); one frame can exceed this transiently, so the bound is
/// checked before parsing, not after encoding.
pub(crate) const MAX_WRITE_BACKLOG: usize = 1 << 20;

/// Bytes one connection may read per scan, so a firehose peer cannot
/// starve its slab-mates on the shared I/O thread.
pub(crate) const READ_BUDGET_PER_SCAN: usize = 64 << 10;

/// Idle/read deadline from `MAPPEROPT_CONN_DEADLINE_S` (seconds;
/// default 300, `0` disables).
fn conn_deadline() -> Option<Duration> {
    let secs = std::env::var("MAPPEROPT_CONN_DEADLINE_S")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(300);
    (secs > 0).then(|| Duration::from_secs(secs))
}

fn env_usize(key: &str) -> Option<usize> {
    std::env::var(key).ok().and_then(|v| v.parse::<usize>().ok())
}

/// Tuning knobs of one [`EvalServer`].  [`Default`] reads the env (the
/// CLI path); tests pass explicit values via [`EvalServer::bind_with`]
/// so they never race on process-global env state.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Size of the I/O thread pool multiplexing all connections (env
    /// `MAPPEROPT_IO_THREADS`; default `min(4, cores)`, min 1).
    pub io_threads: usize,
    /// Concurrent-connection cap; dials beyond it are refused with a
    /// classified `Overloaded` response, counted, and closed (env
    /// `MAPPEROPT_MAX_CONNECTIONS`; default
    /// [`DEFAULT_MAX_CONNECTIONS`]).
    pub max_connections: usize,
    /// Idle/read deadline (env `MAPPEROPT_CONN_DEADLINE_S`, seconds;
    /// default 300; `None` disables reaping).
    pub conn_deadline: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        let cores = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ServerConfig {
            io_threads: env_usize("MAPPEROPT_IO_THREADS")
                .unwrap_or_else(|| cores.min(4))
                .max(1),
            max_connections: env_usize("MAPPEROPT_MAX_CONNECTIONS")
                .unwrap_or(DEFAULT_MAX_CONNECTIONS)
                .max(1),
            conn_deadline: conn_deadline(),
        }
    }
}

// ---------------------------------------------------------------------------
// Per-connection state
// ---------------------------------------------------------------------------

/// One unit of a connection's in-flight evaluation accounting,
/// increment-on-acquire / decrement-on-drop.  The guard is owned by the
/// reply-FIFO entry it accounts for, so *every* teardown path — reply
/// encoded, write error, reap, kill, slab slot dropped wholesale —
/// releases the unit exactly once.  Under slab reuse this is what
/// guarantees a recycled slot starts at zero (the old
/// thread-per-connection server leaked increments on teardown races and
/// got away with it only because the counter died with the threads).
struct InFlightGuard(Arc<AtomicUsize>);

impl InFlightGuard {
    fn acquire(counter: &Arc<AtomicUsize>) -> InFlightGuard {
        counter.fetch_add(1, Ordering::SeqCst);
        InFlightGuard(Arc::clone(counter))
    }
}

impl Drop for InFlightGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// One item of a [`Reply::Batch`]: resolved at admission (shed,
/// bad-request) or pending on a service ticket.
enum BatchSlot {
    Done(BatchItem),
    Ticket { ticket: EvalTicket, guard: InFlightGuard, traced: bool },
}

impl BatchSlot {
    fn ready(&self) -> bool {
        match self {
            BatchSlot::Done(_) => true,
            BatchSlot::Ticket { ticket, .. } => ticket.is_done(),
        }
    }
}

/// One queued reply: ready now (sync requests, protocol errors), a
/// ticket resolving on the worker pool, or a batch of per-item slots
/// answered as one frame.
enum Reply {
    Now(Response),
    Ticket { ticket: EvalTicket, guard: InFlightGuard, traced: bool },
    Batch(Vec<BatchSlot>),
}

impl Reply {
    /// Whether this reply can be encoded without blocking.
    fn ready(&self) -> bool {
        match self {
            Reply::Now(_) => true,
            Reply::Ticket { ticket, .. } => ticket.is_done(),
            Reply::Batch(slots) => slots.iter().all(BatchSlot::ready),
        }
    }

    /// Consume into the wire response (call only when [`Reply::ready`];
    /// the `wait`s below then return without blocking).  The in-flight
    /// guards release here — the accounting unit lives exactly as long
    /// as the queued reply.
    fn into_response(self) -> Response {
        match self {
            Reply::Now(r) => r,
            Reply::Ticket { ticket, guard, traced } => {
                let resp = ticket_response(&ticket, traced);
                drop(guard);
                resp
            }
            Reply::Batch(slots) => Response::FeedbackBatch(
                slots
                    .into_iter()
                    .map(|s| match s {
                        BatchSlot::Done(item) => item,
                        BatchSlot::Ticket { ticket, guard, traced } => {
                            let item = ticket_item(&ticket, traced);
                            drop(guard);
                            item
                        }
                    })
                    .collect(),
            ),
        }
    }
}

/// The telemetry rider travels only on traced replies: untraced frames
/// must stay byte-identical to what pre-trace peers expect, so a client
/// that never opted in never sees the trailing rider.
fn strip_untraced_telemetry(fb: &mut SystemFeedback, traced: bool) {
    if !traced {
        if let SystemFeedback::Performance { telemetry, .. } = fb {
            *telemetry = None;
        }
    }
}

/// Worker panics surface through the ticket as classified
/// execution-error feedback; shed tickets become wire `Overloaded`
/// errors carrying the service's retry-after hint.
fn ticket_response(t: &EvalTicket, traced: bool) -> Response {
    let mut fb = t.wait();
    match t.shed_retry_after_ms() {
        Some(ms) => Response::Error {
            kind: ErrorKind::Overloaded,
            msg: match fb {
                SystemFeedback::ExecutionError(m) => m,
                _ => "request shed under load".into(),
            },
            retry_after_ms: ms,
        },
        None => {
            strip_untraced_telemetry(&mut fb, traced);
            Response::Feedback(fb)
        }
    }
}

/// [`ticket_response`] for one batch item (per-item shedding: a shed
/// candidate does not poison its batch-mates).
fn ticket_item(t: &EvalTicket, traced: bool) -> BatchItem {
    let mut fb = t.wait();
    match t.shed_retry_after_ms() {
        Some(ms) => BatchItem::Error {
            kind: ErrorKind::Overloaded,
            msg: match fb {
                SystemFeedback::ExecutionError(m) => m,
                _ => "request shed under load".into(),
            },
            retry_after_ms: ms,
        },
        None => {
            strip_untraced_telemetry(&mut fb, traced);
            BatchItem::Feedback(fb)
        }
    }
}

/// Slab-resident state of one multiplexed connection (see module docs).
struct ConnState {
    stream: TcpStream,
    /// Bytes read but not yet parsed into frames.
    rbuf: Vec<u8>,
    /// Encoded replies not yet written; `wpos` is the flush cursor.
    wbuf: Vec<u8>,
    wpos: usize,
    /// Replies in request order; the head is polled each scan.
    fifo: VecDeque<Reply>,
    /// Evaluations pending on this connection (see [`InFlightGuard`]).
    in_flight: Arc<AtomicUsize>,
    /// Monotonic byte counters over the write buffer's whole life
    /// (they survive compaction, unlike `wpos`), plus the encode
    /// stamps they resolve: when `flushed_total` passes a mark's
    /// offset, that reply has fully left the buffer and its
    /// encode→drain latency lands in the `ReplyWrite` histogram.
    encoded_total: u64,
    flushed_total: u64,
    write_marks: VecDeque<(u64, Instant)>,
    last_read: Instant,
    /// Last instant the socket accepted bytes while a backlog existed.
    last_write_progress: Instant,
    /// No more requests will be read (EOF, drain, reap, fatal frame);
    /// pending replies still flush before the close.
    read_closed: bool,
    /// Tear down now; queued replies are dropped (guards release).
    dead: bool,
}

impl ConnState {
    fn adopt(stream: TcpStream) -> ConnState {
        let now = Instant::now();
        ConnState {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            fifo: VecDeque::new(),
            in_flight: Arc::new(AtomicUsize::new(0)),
            encoded_total: 0,
            flushed_total: 0,
            write_marks: VecDeque::new(),
            last_read: now,
            last_write_progress: now,
            read_closed: false,
            dead: false,
        }
    }

    fn backlog(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// The connection has nothing left to do and can be closed.
    fn finished(&self) -> bool {
        self.dead || (self.read_closed && self.fifo.is_empty() && self.backlog() == 0)
    }

    /// One readiness scan: read what's there, resolve what's ready,
    /// write what fits, enforce deadlines.  Returns whether any
    /// progress was made (drives the pool's adaptive backoff).
    fn pump(
        &mut self,
        service: &Arc<EvalService>,
        deadline: Option<Duration>,
    ) -> bool {
        let mut progressed = false;
        if !self.read_closed && self.backlog() < MAX_WRITE_BACKLOG {
            progressed |= self.pump_read(service);
        }
        progressed |= self.pump_resolve();
        progressed |= self.pump_write(service);
        self.check_deadline(service, deadline);
        progressed
    }

    /// Drain readable bytes (bounded per scan) and parse whole frames
    /// into queued replies.
    fn pump_read(&mut self, service: &Arc<EvalService>) -> bool {
        let mut progressed = false;
        let mut tmp = [0u8; 16 << 10];
        let mut budget = READ_BUDGET_PER_SCAN;
        while budget > 0 {
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    // clean close (or graceful drain): serve what was
                    // already buffered, then flush and finish
                    self.read_closed = true;
                    break;
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&tmp[..n]);
                    self.last_read = Instant::now();
                    progressed = true;
                    budget = budget.saturating_sub(n);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    return true;
                }
            }
        }
        // peel off every complete frame (up to the reply backpressure
        // bound); a trailing partial frame just waits for more bytes
        while self.fifo.len() < MAX_PENDING_REPLIES {
            match proto::frame_step(&self.rbuf) {
                FrameStep::Incomplete => break,
                FrameStep::Frame { payload, consumed } => {
                    self.rbuf.drain(..consumed);
                    let t_admit = Instant::now();
                    let reply = match Request::decode(&payload) {
                        Ok(req) => serve_request(req, service, &self.in_flight),
                        // version skew / undecodable payloads answer in
                        // place; the length prefix already
                        // resynchronized the stream
                        Err(e) => Reply::Now(Response::Error {
                            kind: e.wire_kind(),
                            msg: e.to_string(),
                            retry_after_ms: 0,
                        }),
                    };
                    // dispatch overhead: frame decode → admitted / shed
                    // / answered (evaluation time is not in here — the
                    // reply is a ticket by now)
                    service
                        .telemetry()
                        .stages
                        .record_since(Stage::Admission, t_admit);
                    self.fifo.push_back(reply);
                    progressed = true;
                }
                FrameStep::Corrupt(msg) => {
                    // unrecoverable framing (bad length or checksum):
                    // classify, answer, close after the flush
                    self.fifo.push_back(Reply::Now(Response::Error {
                        kind: ErrorKind::Frame,
                        msg,
                        retry_after_ms: 0,
                    }));
                    self.rbuf.clear();
                    self.read_closed = true;
                    progressed = true;
                    break;
                }
            }
        }
        progressed
    }

    /// Encode every ready reply at the FIFO head, preserving request
    /// order (an unready head blocks later-but-ready replies — that is
    /// the ordering contract, not a bug).
    fn pump_resolve(&mut self) -> bool {
        let mut progressed = false;
        while self.fifo.front().is_some_and(Reply::ready) {
            let reply = self.fifo.pop_front().expect("checked front");
            let resp = reply.into_response();
            let before = self.wbuf.len();
            if proto::write_frame(&mut self.wbuf, &resp.encode()).is_err() {
                // unencodable reply (oversized frame): the stream can
                // no longer stay in sync — tear down
                self.dead = true;
                return true;
            }
            self.encoded_total += (self.wbuf.len() - before) as u64;
            self.write_marks.push_back((self.encoded_total, Instant::now()));
            progressed = true;
        }
        progressed
    }

    /// Flush the write buffer as far as the socket allows.
    fn pump_write(&mut self, service: &Arc<EvalService>) -> bool {
        let mut progressed = false;
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => {
                    self.wpos += n;
                    self.flushed_total += n as u64;
                    self.last_write_progress = Instant::now();
                    progressed = true;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos > (64 << 10) {
            // partial flush of a large backlog: compact so the buffer
            // tracks unsent bytes, not all bytes ever encoded
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
        // every reply whose last byte just left the buffer closes its
        // encode→drain measurement
        while self
            .write_marks
            .front()
            .is_some_and(|(off, _)| *off <= self.flushed_total)
        {
            let (_, stamped) = self.write_marks.pop_front().expect("checked front");
            service.telemetry().stages.record_since(Stage::ReplyWrite, stamped);
        }
        progressed
    }

    /// Reaping rules (see module docs): idle connections get a polite,
    /// *retryable* [`ErrorKind::Deadline`] answer; connections that
    /// stop draining their replies are closed hard; connections with
    /// evaluations in flight are never reaped.
    fn check_deadline(&mut self, service: &Arc<EvalService>, deadline: Option<Duration>) {
        let Some(d) = deadline else { return };
        if self.dead {
            return;
        }
        if self.backlog() > 0 {
            // replies exist but the peer is not taking them
            if self.last_write_progress.elapsed() > d {
                service.note_reaped_connection();
                self.dead = true;
            }
            return;
        }
        if self.read_closed || !self.fifo.is_empty() {
            return;
        }
        if self.last_read.elapsed() > d {
            service.note_reaped_connection();
            let secs = d.as_secs();
            self.fifo.push_back(Reply::Now(Response::Error {
                kind: ErrorKind::Deadline,
                msg: format!(
                    "connection idle past the {secs}s read deadline; \
                     reconnect and resume"
                ),
                retry_after_ms: 0,
            }));
            self.read_closed = true;
        }
    }
}

// ---------------------------------------------------------------------------
// The I/O pool
// ---------------------------------------------------------------------------

const STATE_RUNNING: u8 = 0;
const STATE_DRAIN: u8 = 1;
const STATE_KILL: u8 = 2;

/// State shared by the acceptor and the I/O pool.
struct ServerShared {
    /// Live + handed-off connections (the acceptor reserves before the
    /// I/O thread adopts; the I/O thread releases on close).
    active: AtomicUsize,
    /// `STATE_RUNNING` / `STATE_DRAIN` / `STATE_KILL`.
    state: AtomicU8,
    /// One hand-off queue per I/O thread (acceptor round-robins).
    inboxes: Vec<Mutex<Vec<TcpStream>>>,
}

fn io_loop(
    idx: usize,
    shared: Arc<ServerShared>,
    service: Arc<EvalService>,
    deadline: Option<Duration>,
) {
    let mut slab: Vec<Option<ConnState>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut idle_spins: u32 = 0;
    loop {
        let state = shared.state.load(Ordering::SeqCst);
        let incoming: Vec<TcpStream> = {
            let mut q = shared.inboxes[idx].lock().unwrap();
            std::mem::take(&mut *q)
        };
        let mut progressed = !incoming.is_empty();
        for stream in incoming {
            if state == STATE_KILL {
                let _ = stream.shutdown(Shutdown::Both);
                shared.active.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
            let conn = ConnState::adopt(stream);
            match free.pop() {
                Some(i) => slab[i] = Some(conn),
                None => slab.push(Some(conn)),
            }
        }
        for slot in 0..slab.len() {
            let finished = {
                let Some(conn) = slab[slot].as_mut() else { continue };
                match state {
                    STATE_KILL => conn.dead = true,
                    STATE_DRAIN => conn.read_closed = true,
                    _ => {}
                }
                if !conn.dead {
                    progressed |= conn.pump(&service, deadline);
                }
                conn.finished()
            };
            if finished {
                if let Some(conn) = slab[slot].take() {
                    let _ = conn.stream.shutdown(Shutdown::Both);
                    // dropping the state here drops any queued replies,
                    // whose guards release their in-flight units
                }
                free.push(slot);
                shared.active.fetch_sub(1, Ordering::SeqCst);
                progressed = true;
            }
        }
        if state != STATE_RUNNING
            && slab.iter().all(Option::is_none)
            && shared.inboxes[idx].lock().unwrap().is_empty()
        {
            break;
        }
        if progressed {
            idle_spins = 0;
        } else {
            // adaptive backoff: yield first, then microsleeps ramping
            // to 500µs — idle costs ~nothing, activity is picked up
            // within half a millisecond
            idle_spins = idle_spins.saturating_add(1);
            if idle_spins <= 3 {
                thread::yield_now();
            } else {
                let us = (50 * idle_spins as u64).min(500);
                thread::sleep(Duration::from_micros(us));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The server front
// ---------------------------------------------------------------------------

/// A TCP front over one shared [`EvalService`] (see module docs).
/// Binding spawns the acceptor and the I/O pool; [`EvalServer::join`]
/// blocks for a serve-forever process.  [`EvalServer::shutdown`] (and
/// plain drop) drains gracefully: stop accepting, answer in-flight
/// work, close.  [`EvalServer::kill`] severs every connection abruptly
/// instead.
pub struct EvalServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
    io: Vec<thread::JoinHandle<()>>,
    shared: Arc<ServerShared>,
}

impl EvalServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral test port)
    /// with env-derived [`ServerConfig`] defaults.
    pub fn bind(addr: &str, service: Arc<EvalService>) -> io::Result<EvalServer> {
        EvalServer::bind_with(addr, service, ServerConfig::default())
    }

    /// [`EvalServer::bind`] with explicit knobs (tests pin the
    /// connection cap / deadline here instead of racing on env vars).
    pub fn bind_with(
        addr: &str,
        service: Arc<EvalService>,
        config: ServerConfig,
    ) -> io::Result<EvalServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let io_threads = config.io_threads.max(1);
        let max_connections = config.max_connections.max(1);
        let deadline = config.conn_deadline;
        let shared = Arc::new(ServerShared {
            active: AtomicUsize::new(0),
            state: AtomicU8::new(STATE_RUNNING),
            inboxes: (0..io_threads).map(|_| Mutex::new(Vec::new())).collect(),
        });
        let mut io = Vec::with_capacity(io_threads);
        for i in 0..io_threads {
            let shared = Arc::clone(&shared);
            let service = Arc::clone(&service);
            io.push(
                thread::Builder::new()
                    .name(format!("evalsrv-io-{i}"))
                    .spawn(move || io_loop(i, shared, service, deadline))?,
            );
        }
        let accept_shared = Arc::clone(&shared);
        let accept = thread::Builder::new()
            .name("evalsrv-accept".into())
            .spawn(move || {
                let mut next = 0usize;
                for conn in listener.incoming() {
                    if stop_flag.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(mut stream) => {
                            // reserve a slot; over capacity: classified
                            // refusal — counted, answered, and the
                            // stream closed *explicitly* (never left
                            // half-open for the peer to time out on)
                            let prev =
                                accept_shared.active.fetch_add(1, Ordering::SeqCst);
                            if prev >= max_connections {
                                accept_shared.active.fetch_sub(1, Ordering::SeqCst);
                                service.note_refused_connection();
                                let resp = Response::Error {
                                    kind: ErrorKind::Overloaded,
                                    msg: format!(
                                        "server at connection capacity \
                                         ({max_connections})"
                                    ),
                                    retry_after_ms: 250,
                                };
                                let _ =
                                    proto::write_frame(&mut stream, &resp.encode());
                                let _ = stream.shutdown(Shutdown::Both);
                                continue;
                            }
                            let _ = stream.set_nodelay(true);
                            if stream.set_nonblocking(true).is_err() {
                                accept_shared.active.fetch_sub(1, Ordering::SeqCst);
                                continue;
                            }
                            let inbox = next % accept_shared.inboxes.len();
                            next = next.wrapping_add(1);
                            accept_shared.inboxes[inbox].lock().unwrap().push(stream);
                        }
                        // transient accept errors (EMFILE, aborted
                        // handshakes) must not kill the server — but
                        // back off so a persistent error (fd
                        // exhaustion) cannot busy-spin this thread
                        Err(_) => {
                            thread::sleep(Duration::from_millis(50));
                            continue;
                        }
                    }
                }
            })?;
        Ok(EvalServer { addr: local, stop, accept: Some(accept), io, shared })
    }

    /// The bound address (resolves the ephemeral port of `":0"` binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the I/O pool exits (the serve-forever CLI path).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.io.drain(..) {
            let _ = h.join();
        }
    }

    /// Graceful drain: stop accepting, stop reading new requests, let
    /// the pool answer everything already in flight, flush, and join —
    /// a restart never strands a pending ticket.
    pub fn shutdown(mut self) {
        self.drain();
    }

    /// Abrupt stop: sever every live connection both ways (in-flight
    /// replies are lost — clients observe a dead socket, exactly like a
    /// crashed process) and stop accepting.  The fault-injection tests
    /// use this to simulate a server crash; everything else should
    /// prefer [`EvalServer::shutdown`].
    pub fn kill(mut self) {
        self.stop_accepting();
        self.shared.state.store(STATE_KILL, Ordering::SeqCst);
        for h in self.io.drain(..) {
            let _ = h.join();
        }
    }

    fn drain(&mut self) {
        self.stop_accepting();
        // never downgrade a kill in progress
        let _ = self.shared.state.compare_exchange(
            STATE_RUNNING,
            STATE_DRAIN,
            Ordering::SeqCst,
            Ordering::SeqCst,
        );
        for h in self.io.drain(..) {
            let _ = h.join();
        }
    }

    fn stop_accepting(&mut self) {
        if let Some(h) = self.accept.take() {
            self.stop.store(true, Ordering::SeqCst);
            // unblock the blocking accept with a throwaway connection;
            // a wildcard bind (0.0.0.0 / ::) is not connectable on
            // every platform, so aim the wake-up at loopback instead
            let mut target = self.addr;
            if target.ip().is_unspecified() {
                let loopback = match target.ip() {
                    IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                    IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
                };
                target.set_ip(loopback);
            }
            let _ = TcpStream::connect(target);
            let _ = h.join();
        }
    }
}

impl Drop for EvalServer {
    fn drop(&mut self) {
        self.drain();
    }
}

// ---------------------------------------------------------------------------
// Request dispatch
// ---------------------------------------------------------------------------

fn bad_request(msg: String) -> Reply {
    Reply::Now(Response::Error {
        kind: ErrorKind::BadRequest,
        msg,
        retry_after_ms: 0,
    })
}

/// Answer for an eval submitted past [`MAX_CONN_IN_FLIGHT`] (counted as
/// a shed submission at the service).
fn conn_cap_msg() -> String {
    format!(
        "connection has {MAX_CONN_IN_FLIGHT} evaluations in \
         flight; drain replies before submitting more"
    )
}

fn serve_request(
    req: Request,
    service: &Arc<EvalService>,
    in_flight: &Arc<AtomicUsize>,
) -> Reply {
    match req {
        Request::Ping => Reply::Now(Response::Pong),
        Request::Eval(q) => {
            if in_flight.load(Ordering::SeqCst) >= MAX_CONN_IN_FLIGHT {
                // connection-level admission control: answered in place
                // (counted as a shed submission), so one pipelining
                // client cannot build an unbounded ticket backlog
                service.note_shed_at_connection();
                return Reply::Now(Response::Error {
                    kind: ErrorKind::Overloaded,
                    msg: conn_cap_msg(),
                    retry_after_ms: 25,
                });
            }
            let traced = q.trace_id != 0;
            match prepare_eval(q, service) {
                // non-blocking admission: at the queue's high-water
                // mark the service sheds lowest-priority work and the
                // ticket resolves as Overloaded
                Ok(req) => Reply::Ticket {
                    guard: InFlightGuard::acquire(in_flight),
                    ticket: service.try_submit(req),
                    traced,
                },
                Err(msg) => bad_request(msg),
            }
        }
        Request::EvalBatch(items) => {
            // per-item admission: each candidate passes the in-flight
            // cap, bad-request validation, and queue shedding on its
            // own, so one bad/unlucky item cannot poison the batch
            let slots = items
                .into_iter()
                .map(|q| {
                    if in_flight.load(Ordering::SeqCst) >= MAX_CONN_IN_FLIGHT {
                        service.note_shed_at_connection();
                        return BatchSlot::Done(BatchItem::Error {
                            kind: ErrorKind::Overloaded,
                            msg: conn_cap_msg(),
                            retry_after_ms: 25,
                        });
                    }
                    let traced = q.trace_id != 0;
                    match prepare_eval(q, service) {
                        Ok(req) => BatchSlot::Ticket {
                            guard: InFlightGuard::acquire(in_flight),
                            ticket: service.try_submit(req),
                            traced,
                        },
                        Err(msg) => BatchSlot::Done(BatchItem::Error {
                            kind: ErrorKind::BadRequest,
                            msg,
                            retry_after_ms: 0,
                        }),
                    }
                })
                .collect();
            Reply::Batch(slots)
        }
        Request::RegisterSpec { name, spec } => {
            if name.len() > MAX_SPEC_NAME_BYTES
                || spec.name.len() > MAX_SPEC_NAME_BYTES
            {
                bad_request(format!(
                    "spec names are limited to {MAX_SPEC_NAME_BYTES} bytes"
                ))
            } else {
                // capped atomically under the registry lock, so racing
                // registrations cannot overshoot the bound
                match service.registry().register_bounded(
                    &name,
                    spec,
                    MAX_REGISTERED_SPECS,
                ) {
                    Some(id) => Reply::Now(spec_info(service, id)),
                    None => bad_request(format!(
                        "spec registry is full ({MAX_REGISTERED_SPECS} entries); \
                         reuse a registered spec"
                    )),
                }
            }
        }
        Request::GetSpec { name } => match service.spec_id(&name) {
            Some(id) => Reply::Now(spec_info(service, id)),
            None => bad_request(format!("unknown machine spec '{name}'")),
        },
        Request::Stats => Reply::Now(Response::Stats(service.snapshot())),
        Request::Summary => Reply::Now(Response::Summary(service.summary())),
        Request::TraceDump => {
            Reply::Now(Response::TraceDump(service.trace_dump()))
        }
    }
}

fn spec_info(service: &EvalService, id: crate::coordinator::SpecId) -> Response {
    Response::SpecInfo {
        id: id.index() as u32,
        name: service.registry().name(id),
        spec: service.spec(id),
    }
}

/// Resolve the wire request into a service request: spec ref against
/// the registry, scenario into a concrete [`App`](crate::apps::App).
/// Errors are bad-request messages (the caller wraps them for the
/// single or batch reply shape).
fn prepare_eval(
    q: WireEvalRequest,
    service: &Arc<EvalService>,
) -> Result<EvalRequest, String> {
    let spec_id = match &q.spec {
        SpecRef::Id(i) => service
            .registry()
            .by_index(*i as usize)
            .ok_or_else(|| format!("unknown machine spec id {i}"))?,
        SpecRef::Name(n) => service
            .spec_id(n)
            .ok_or_else(|| format!("unknown machine spec '{n}'"))?,
    };
    let app = apps::scenario(&q.scenario.app, &q.scenario.params)?;
    // budget the graph before any engine materializes it, summing every
    // step's launches — launch structure can vary per step (Solomonik
    // adds its reduce launch only on the last one), so pricing step 0
    // alone would undercount; the early break keeps this loop itself
    // budget-bounded for huge step counts
    let mut total: i64 = 0;
    for step in 0..app.steps {
        let per_step: i64 = app.launches(step).iter().map(|l| l.num_points()).sum();
        total = total.saturating_add(per_step);
        if total > MAX_REQUEST_POINT_TASKS {
            return Err(format!(
                "scenario '{}' describes over {total} point tasks, over the \
                 per-request budget of {MAX_REQUEST_POINT_TASKS}",
                q.scenario.app
            ));
        }
    }
    Ok(EvalRequest {
        spec_id,
        app: Arc::new(app),
        dsl: q.dsl,
        mode: q.mode,
        priority: q.priority,
        trace_id: q.trace_id,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::ExecMode;
    use super::super::proto::Scenario;

    fn service() -> Arc<EvalService> {
        Arc::new(EvalService::new(2, 16))
    }

    fn wire_eval() -> WireEvalRequest {
        WireEvalRequest {
            spec: SpecRef::Name("p100_cluster".into()),
            scenario: Scenario::named("circuit"),
            dsl: crate::mapping::expert_dsl("circuit").unwrap().into(),
            mode: ExecMode::Serialized,
            priority: 128,
            trace_id: 0,
        }
    }

    #[test]
    fn trace_dump_requests_answer_in_place_and_untraced_replies_lose_the_rider() {
        let svc = service();
        let counter = Arc::new(AtomicUsize::new(0));
        match serve_request(Request::TraceDump, &svc, &counter) {
            Reply::Now(Response::TraceDump(spans)) => {
                assert!(spans.is_empty(), "fresh service has no spans")
            }
            _ => panic!("trace dump must answer in place"),
        }
        // untraced eval: telemetry stripped before the wire
        let reply = serve_request(Request::Eval(wire_eval()), &svc, &counter);
        if let Reply::Ticket { ticket, .. } = &reply {
            let _ = ticket.wait();
        }
        match reply.into_response() {
            Response::Feedback(fb) => {
                assert!(fb.telemetry().is_none(), "untraced reply keeps no rider")
            }
            other => panic!("wrong variant {}", other.kind_name()),
        }
        // traced eval: the rider survives and a span lands in the ring
        let traced = WireEvalRequest { trace_id: 0xBEEF, ..wire_eval() };
        let reply = serve_request(Request::Eval(traced), &svc, &counter);
        if let Reply::Ticket { ticket, .. } = &reply {
            let _ = ticket.wait();
        }
        match reply.into_response() {
            Response::Feedback(fb) => {
                assert!(fb.telemetry().is_some(), "traced reply carries the rider")
            }
            other => panic!("wrong variant {}", other.kind_name()),
        }
        let spans = svc.trace_dump();
        assert!(
            spans.iter().any(|s| s.trace_id == 0xBEEF),
            "traced request must land a span"
        );
    }

    #[test]
    fn in_flight_accounting_is_a_drop_guard_owned_by_the_reply() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let g = InFlightGuard::acquire(&counter);
            assert_eq!(counter.load(Ordering::SeqCst), 1);
            drop(g);
        }
        assert_eq!(counter.load(Ordering::SeqCst), 0);

        // a reply FIFO torn down with queued work (the client vanished)
        // releases every unit — single tickets and batch slots alike
        let svc = service();
        let mut fifo: VecDeque<Reply> = VecDeque::new();
        fifo.push_back(serve_request(
            Request::Eval(wire_eval()),
            &svc,
            &counter,
        ));
        fifo.push_back(serve_request(
            Request::EvalBatch(vec![wire_eval(), wire_eval()]),
            &svc,
            &counter,
        ));
        assert_eq!(
            counter.load(Ordering::SeqCst),
            3,
            "one single + two batch items in flight"
        );
        drop(fifo);
        assert_eq!(
            counter.load(Ordering::SeqCst),
            0,
            "teardown with queued replies must release every unit"
        );

        // slab-slot reuse: a recycled slot's accounting starts at zero
        // and the first acquisition on it counts from there
        let g = InFlightGuard::acquire(&counter);
        assert_eq!(counter.load(Ordering::SeqCst), 1);
        drop(g);
        assert_eq!(counter.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn resolved_replies_release_their_units_too() {
        let counter = Arc::new(AtomicUsize::new(0));
        let svc = service();
        let reply = serve_request(Request::Eval(wire_eval()), &svc, &counter);
        assert_eq!(counter.load(Ordering::SeqCst), 1);
        // wait out the ticket, then consume the reply the way the
        // write path does
        if let Reply::Ticket { ticket, .. } = &reply {
            let _ = ticket.wait();
        }
        assert!(reply.ready());
        match reply.into_response() {
            Response::Feedback(fb) => assert!(fb.score() > 0.0),
            other => panic!("wrong variant {}", other.kind_name()),
        }
        assert_eq!(counter.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn per_connection_cap_applies_per_batch_item() {
        let svc = service();
        let counter = Arc::new(AtomicUsize::new(MAX_CONN_IN_FLIGHT));
        match serve_request(Request::Eval(wire_eval()), &svc, &counter) {
            Reply::Now(Response::Error { kind, retry_after_ms, .. }) => {
                assert_eq!(kind, ErrorKind::Overloaded);
                assert!(retry_after_ms > 0, "shed must carry a hint");
            }
            _ => panic!("eval over the cap must be answered in place"),
        }
        match serve_request(
            Request::EvalBatch(vec![wire_eval(), wire_eval()]),
            &svc,
            &counter,
        ) {
            Reply::Batch(slots) => {
                assert_eq!(slots.len(), 2);
                for s in &slots {
                    match s {
                        BatchSlot::Done(BatchItem::Error { kind, .. }) => {
                            assert_eq!(*kind, ErrorKind::Overloaded);
                        }
                        _ => panic!("batch items over the cap must shed"),
                    }
                }
            }
            _ => panic!("a batch request must answer as a batch"),
        }
        // refusals never touch the accounting
        assert_eq!(counter.load(Ordering::SeqCst), MAX_CONN_IN_FLIGHT);
    }
}
