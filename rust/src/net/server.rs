//! The threaded TCP eval server: drains decoded requests into one
//! shared [`EvalService`], so remote clients hit the same feedback /
//! plan / policy / decision caches and in-flight deduplication as
//! local ones.
//!
//! One thread accepts connections; each connection gets a reader thread
//! (this one) plus a writer thread.  The reader decodes frames and
//! turns them into replies *immediately* — synchronous requests resolve
//! inline, evaluations become [`EvalTicket`]s admitted to the service's
//! priority queue via the non-blocking
//! [`EvalService::try_submit`](crate::coordinator::EvalService::try_submit)
//! — and hands them to the writer in arrival order.  The writer waits
//! each ticket and encodes the response, so responses keep request
//! order (the client matches FIFO) while the evaluations themselves run
//! concurrently on the service's worker pool, interleaved with every
//! other client's.
//!
//! # Self-protection
//!
//! The serving path never queues or blocks without bound:
//!
//! * **Queue high-water shedding** — at the service's high-water mark,
//!   lowest-priority work is shed with a classified
//!   [`ErrorKind::Overloaded`] response carrying a retry-after hint
//!   (see [`CacheConfig::queue_high_water`]); readers never block on a
//!   full queue.
//! * **Per-connection in-flight cap** — a connection may keep at most
//!   [`MAX_CONN_IN_FLIGHT`] evaluations pending; excess submissions are
//!   answered `Overloaded` immediately, so one client cannot pin the
//!   writer behind an unbounded ticket backlog.
//! * **Idle/read deadline** — a connection that sends nothing for
//!   `MAPPEROPT_CONN_DEADLINE_S` seconds (default 300; `0` disables)
//!   is reaped: counted in
//!   [`ServiceStats::reaped_connections`](crate::coordinator::ServiceStats),
//!   answered with a best-effort classified error, and closed — zombie
//!   peers cannot hold threads and sockets forever.
//! * **Graceful drain** — [`EvalServer::shutdown`] stops accepting,
//!   half-closes every live connection (read side), lets the writers
//!   answer all in-flight tickets, and joins the connection threads, so
//!   restarts never strand a pending reply.  [`EvalServer::kill`] is
//!   the abrupt variant (both sides severed, in-flight replies lost) —
//!   what the fault-injection tests use to simulate a crash.
//!
//! Fault containment: framing errors (including checksum mismatches),
//! version skew, undecodable payloads, unknown specs/apps, and worker
//! panics are all answered as classified responses
//! ([`proto::Response::Error`] or error-carrying feedback), never
//! connection aborts — the only hard close is an unrecoverable frame,
//! answered first.
//!
//! [`CacheConfig::queue_high_water`]: crate::coordinator::CacheConfig

use std::collections::HashMap;
use std::io;
use std::net::{
    IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream,
};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Duration;

use crate::apps;
use crate::coordinator::{EvalRequest, EvalService, EvalTicket};
use crate::feedback::SystemFeedback;

use super::proto::{
    self, ErrorKind, Request, Response, SpecRef, WireEvalRequest,
};

/// One queued reply: either ready now (sync requests, protocol errors)
/// or a ticket the writer resolves in order.
enum Reply {
    Now(Response),
    Ticket(EvalTicket),
}

/// Per-request budget on the simulated task graph a remote scenario may
/// ask for: `apps::scenario`'s per-parameter bounds keep the arithmetic
/// sane, but a product of in-range extents can still describe a graph
/// whose materialization would exhaust memory — and an allocation
/// failure *aborts* the shared server (it does not unwind into the
/// worker-panic containment).  Oversized scenarios classify as bad
/// requests instead.
const MAX_REQUEST_POINT_TASKS: i64 = 1 << 24;

/// Registered machine specs are deduplicated by fingerprint but the
/// registry itself is append-only (ids must stay stable), so remote
/// registration is capped — the one piece of service state a client
/// could otherwise grow without bound.
const MAX_REGISTERED_SPECS: usize = 1024;

/// Registry entries live forever and their names are re-cloned by every
/// summary/stats request, so a registered name (the alias *and* the
/// name embedded in the spec) may not exceed this — otherwise the entry
/// cap above still admits gigabytes of hostile name bytes.
const MAX_SPEC_NAME_BYTES: usize = 256;

/// Each connection costs two OS threads (reader + writer) and a cloned
/// socket; beyond this many concurrent connections the server answers a
/// classified capacity error and closes instead of exhausting
/// threads/fds under a reconnect storm.
const MAX_CONNECTIONS: usize = 256;

/// Evaluations one connection may keep pending at once; submissions
/// past the cap are answered [`ErrorKind::Overloaded`] immediately
/// (counted as shed), so a single pipelining client cannot build an
/// unbounded ticket backlog behind its writer.
pub const MAX_CONN_IN_FLIGHT: usize = 64;

/// Idle/read deadline from `MAPPEROPT_CONN_DEADLINE_S` (seconds;
/// default 300, `0` disables).
fn conn_deadline() -> Option<Duration> {
    let secs = std::env::var("MAPPEROPT_CONN_DEADLINE_S")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(300);
    (secs > 0).then(|| Duration::from_secs(secs))
}

/// Live-connection registry: the accept loop registers every served
/// stream (for drain/kill) and its thread handle (for join), and the
/// per-connection guard unregisters on exit — including panicking
/// exits, so a fault can never leak capacity.
#[derive(Default)]
struct ConnRegistry {
    active: AtomicUsize,
    next_id: AtomicUsize,
    streams: Mutex<HashMap<usize, TcpStream>>,
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl ConnRegistry {
    /// Half- or full-close every live connection.
    fn sever(&self, how: Shutdown) {
        let g = self.streams.lock().unwrap();
        for s in g.values() {
            let _ = s.shutdown(how);
        }
    }

    /// Join every connection thread (called after the acceptor has
    /// stopped, so no new handles appear concurrently).
    fn join_all(&self) {
        let handles: Vec<_> = {
            let mut g = self.handles.lock().unwrap();
            g.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }

    /// Drop handles of connections that already exited, so a long-lived
    /// server's handle list stays O(live connections).
    fn prune_finished(&self) {
        self.handles.lock().unwrap().retain(|h| !h.is_finished());
    }
}

/// Releases one connection slot (and its stream registration) on drop.
struct ConnSlot {
    registry: Arc<ConnRegistry>,
    id: usize,
}

impl Drop for ConnSlot {
    fn drop(&mut self) {
        self.registry.streams.lock().unwrap().remove(&self.id);
        self.registry.active.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A TCP front over one shared [`EvalService`] (see module docs).
/// Binding spawns the accept loop; [`EvalServer::join`] blocks for a
/// serve-forever process.  [`EvalServer::shutdown`] (and plain drop)
/// drains gracefully: stop accepting, answer in-flight work, close.
/// [`EvalServer::kill`] severs every connection abruptly instead.
pub struct EvalServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
    conns: Arc<ConnRegistry>,
}

impl EvalServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral test port)
    /// and start accepting; every connection is served against
    /// `service`.
    pub fn bind(addr: &str, service: Arc<EvalService>) -> io::Result<EvalServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let conns = Arc::new(ConnRegistry::default());
        let registry = Arc::clone(&conns);
        let deadline = conn_deadline();
        let accept = thread::Builder::new()
            .name("evalsrv-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop_flag.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(mut stream) => {
                            registry.prune_finished();
                            if registry.active.load(Ordering::SeqCst)
                                >= MAX_CONNECTIONS
                            {
                                // classified refusal, then close
                                let resp = Response::Error {
                                    kind: ErrorKind::Overloaded,
                                    msg: format!(
                                        "server at connection capacity \
                                         ({MAX_CONNECTIONS})"
                                    ),
                                    retry_after_ms: 250,
                                };
                                let _ = proto::write_frame(&mut stream, &resp.encode());
                                continue;
                            }
                            registry.active.fetch_add(1, Ordering::SeqCst);
                            let id = registry.next_id.fetch_add(1, Ordering::SeqCst);
                            if let Ok(clone) = stream.try_clone() {
                                registry.streams.lock().unwrap().insert(id, clone);
                            }
                            let service = Arc::clone(&service);
                            let slot =
                                ConnSlot { registry: Arc::clone(&registry), id };
                            // on spawn failure the closure (stream +
                            // guard) is dropped, and the guard's Drop
                            // releases the reservation either way
                            let spawned = thread::Builder::new()
                                .name("evalsrv-conn".into())
                                .spawn(move || {
                                    // held for the connection's life:
                                    // released on return *and* on panic
                                    let _slot = slot;
                                    handle_conn(stream, service, deadline);
                                });
                            if let Ok(h) = spawned {
                                registry.handles.lock().unwrap().push(h);
                            }
                        }
                        // transient accept errors (EMFILE, aborted
                        // handshakes) must not kill the server — but
                        // back off so a persistent error (fd
                        // exhaustion) cannot busy-spin this thread
                        Err(_) => {
                            thread::sleep(Duration::from_millis(50));
                            continue;
                        }
                    }
                }
            })?;
        Ok(EvalServer { addr: local, stop, accept: Some(accept), conns })
    }

    /// The bound address (resolves the ephemeral port of `":0"` binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the accept loop exits (the serve-forever CLI path).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Graceful drain: stop accepting, half-close every live connection
    /// (readers see a clean end-of-stream and stop taking requests),
    /// let the writers answer everything already in flight, and join
    /// the connection threads — a restart never strands a pending
    /// ticket.
    pub fn shutdown(mut self) {
        self.drain();
    }

    /// Abrupt stop: sever every live connection both ways (in-flight
    /// replies are lost — clients observe a dead socket, exactly like a
    /// crashed process) and stop accepting.  The fault-injection tests
    /// use this to simulate a server crash; everything else should
    /// prefer [`EvalServer::shutdown`].
    pub fn kill(mut self) {
        self.stop_accepting();
        self.conns.sever(Shutdown::Both);
        self.conns.join_all();
        self.accept = None;
    }

    fn drain(&mut self) {
        self.stop_accepting();
        // acceptor is joined: the registry is stable from here on
        self.conns.sever(Shutdown::Read);
        self.conns.join_all();
    }

    fn stop_accepting(&mut self) {
        if let Some(h) = self.accept.take() {
            self.stop.store(true, Ordering::SeqCst);
            // unblock the blocking accept with a throwaway connection;
            // a wildcard bind (0.0.0.0 / ::) is not connectable on
            // every platform, so aim the wake-up at loopback instead
            let mut target = self.addr;
            if target.ip().is_unspecified() {
                let loopback = match target.ip() {
                    IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                    IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
                };
                target.set_ip(loopback);
            }
            let _ = TcpStream::connect(target);
            let _ = h.join();
        }
    }
}

impl Drop for EvalServer {
    fn drop(&mut self) {
        self.drain();
    }
}

/// Per-connection reader: decode frames, resolve or enqueue, preserve
/// order through the writer channel.
fn handle_conn(
    stream: TcpStream,
    service: Arc<EvalService>,
    deadline: Option<Duration>,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(deadline);
    let mut reader = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    // evaluations this connection has pending: inc'd by the reader when
    // a ticket is queued, dec'd by the writer once its reply is sent
    let in_flight = Arc::new(AtomicUsize::new(0));
    let in_flight_w = Arc::clone(&in_flight);
    let (tx, rx) = mpsc::channel::<Reply>();
    let writer = thread::Builder::new()
        .name("evalsrv-write".into())
        .spawn(move || {
            let mut out = stream;
            for reply in rx {
                let resp = match reply {
                    Reply::Now(r) => r,
                    // worker panics surface through the ticket as
                    // classified execution-error feedback; shed tickets
                    // become wire Overloaded errors with the hint
                    Reply::Ticket(t) => {
                        let fb = t.wait();
                        in_flight_w.fetch_sub(1, Ordering::SeqCst);
                        match t.shed_retry_after_ms() {
                            Some(ms) => Response::Error {
                                kind: ErrorKind::Overloaded,
                                msg: match fb {
                                    SystemFeedback::ExecutionError(m) => m,
                                    _ => "request shed under load".into(),
                                },
                                retry_after_ms: ms,
                            },
                            None => Response::Feedback(fb),
                        }
                    }
                };
                if proto::write_frame(&mut out, &resp.encode()).is_err() {
                    // client gone: remaining queued replies are simply
                    // dropped — pending evaluations still complete on
                    // the service's workers, their tickets just have no
                    // reader anymore
                    break;
                }
            }
            let _ = out.shutdown(Shutdown::Both);
        });
    let Ok(writer) = writer else { return };

    loop {
        let payload = match proto::read_frame(&mut reader) {
            Ok(Some(p)) => p,
            Ok(None) => break, // clean close (or graceful drain)
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                // idle past the read deadline: reap the zombie — count
                // it, answer best-effort, close
                service.note_reaped_connection();
                let secs = deadline.map_or(0, |d| d.as_secs());
                let _ = tx.send(Reply::Now(Response::Error {
                    kind: ErrorKind::Internal,
                    msg: format!(
                        "connection idle past the {secs}s read deadline; closing"
                    ),
                    retry_after_ms: 0,
                }));
                break;
            }
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                // unrecoverable framing (bad length or checksum):
                // classify, answer, close
                let _ = tx.send(Reply::Now(Response::Error {
                    kind: ErrorKind::Frame,
                    msg: e.to_string(),
                    retry_after_ms: 0,
                }));
                break;
            }
            Err(_) => break, // transport failure
        };
        let reply = match Request::decode(&payload) {
            Ok(req) => serve_request(req, &service, &in_flight),
            // version skew / undecodable payloads answer in place; the
            // length prefix already resynchronized the stream
            Err(e) => Reply::Now(Response::Error {
                kind: e.wire_kind(),
                msg: e.to_string(),
                retry_after_ms: 0,
            }),
        };
        if let Reply::Ticket(_) = &reply {
            in_flight.fetch_add(1, Ordering::SeqCst);
        }
        if tx.send(reply).is_err() {
            break;
        }
    }
    drop(tx);
    let _ = writer.join();
}

fn bad_request(msg: String) -> Reply {
    Reply::Now(Response::Error {
        kind: ErrorKind::BadRequest,
        msg,
        retry_after_ms: 0,
    })
}

fn serve_request(
    req: Request,
    service: &Arc<EvalService>,
    in_flight: &AtomicUsize,
) -> Reply {
    match req {
        Request::Ping => Reply::Now(Response::Pong),
        Request::Eval(q) => {
            if in_flight.load(Ordering::SeqCst) >= MAX_CONN_IN_FLIGHT {
                // connection-level admission control: answered in place
                // (counted as a shed submission), so one pipelining
                // client cannot build an unbounded ticket backlog
                service.note_shed_at_connection();
                return Reply::Now(Response::Error {
                    kind: ErrorKind::Overloaded,
                    msg: format!(
                        "connection has {MAX_CONN_IN_FLIGHT} evaluations in \
                         flight; drain replies before submitting more"
                    ),
                    retry_after_ms: 25,
                });
            }
            match prepare_eval(q, service) {
                // non-blocking admission: at the queue's high-water
                // mark the service sheds lowest-priority work and the
                // ticket resolves as Overloaded (see the writer)
                Ok(req) => Reply::Ticket(service.try_submit(req)),
                Err(reply) => reply,
            }
        }
        Request::RegisterSpec { name, spec } => {
            if name.len() > MAX_SPEC_NAME_BYTES
                || spec.name.len() > MAX_SPEC_NAME_BYTES
            {
                bad_request(format!(
                    "spec names are limited to {MAX_SPEC_NAME_BYTES} bytes"
                ))
            } else {
                // capped atomically under the registry lock, so racing
                // registrations cannot overshoot the bound
                match service.registry().register_bounded(
                    &name,
                    spec,
                    MAX_REGISTERED_SPECS,
                ) {
                    Some(id) => Reply::Now(spec_info(service, id)),
                    None => bad_request(format!(
                        "spec registry is full ({MAX_REGISTERED_SPECS} entries); \
                         reuse a registered spec"
                    )),
                }
            }
        }
        Request::GetSpec { name } => match service.spec_id(&name) {
            Some(id) => Reply::Now(spec_info(service, id)),
            None => bad_request(format!("unknown machine spec '{name}'")),
        },
        Request::Stats => Reply::Now(Response::Stats(service.snapshot())),
        Request::Summary => Reply::Now(Response::Summary(service.summary())),
    }
}

fn spec_info(service: &EvalService, id: crate::coordinator::SpecId) -> Response {
    Response::SpecInfo {
        id: id.index() as u32,
        name: service.registry().name(id),
        spec: service.spec(id),
    }
}

/// Resolve the wire request into a service request: spec ref against
/// the registry, scenario into a concrete [`App`](crate::apps::App).
fn prepare_eval(
    q: WireEvalRequest,
    service: &Arc<EvalService>,
) -> Result<EvalRequest, Reply> {
    let spec_id = match &q.spec {
        SpecRef::Id(i) => service
            .registry()
            .by_index(*i as usize)
            .ok_or_else(|| bad_request(format!("unknown machine spec id {i}")))?,
        SpecRef::Name(n) => service
            .spec_id(n)
            .ok_or_else(|| bad_request(format!("unknown machine spec '{n}'")))?,
    };
    let app = apps::scenario(&q.scenario.app, &q.scenario.params)
        .map_err(bad_request)?;
    // budget the graph before any engine materializes it, summing every
    // step's launches — launch structure can vary per step (Solomonik
    // adds its reduce launch only on the last one), so pricing step 0
    // alone would undercount; the early break keeps this loop itself
    // budget-bounded for huge step counts
    let mut total: i64 = 0;
    for step in 0..app.steps {
        let per_step: i64 = app.launches(step).iter().map(|l| l.num_points()).sum();
        total = total.saturating_add(per_step);
        if total > MAX_REQUEST_POINT_TASKS {
            return Err(bad_request(format!(
                "scenario '{}' describes over {total} point tasks, over the \
                 per-request budget of {MAX_REQUEST_POINT_TASKS}",
                q.scenario.app
            )));
        }
    }
    Ok(EvalRequest {
        spec_id,
        app: Arc::new(app),
        dsl: q.dsl,
        mode: q.mode,
        priority: q.priority,
    })
}
