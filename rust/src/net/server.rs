//! The threaded TCP eval server: drains decoded requests into one
//! shared [`EvalService`], so remote clients hit the same feedback /
//! plan / policy / decision caches and in-flight deduplication as
//! local ones.
//!
//! One thread accepts connections; each connection gets a reader thread
//! (this one) plus a writer thread.  The reader decodes frames and
//! turns them into replies *immediately* — synchronous requests resolve
//! inline, evaluations become [`EvalTicket`]s submitted to the
//! service's priority queue — and hands them to the writer in arrival
//! order.  The writer waits each ticket and encodes the response, so
//! responses keep request order (the client matches FIFO) while the
//! evaluations themselves run concurrently on the service's worker
//! pool, interleaved with every other client's.
//!
//! Fault containment: framing errors, version skew, undecodable
//! payloads, unknown specs/apps, and worker panics are all answered as
//! classified responses ([`proto::Response::Error`] or error-carrying
//! feedback), never connection aborts — the only hard close is an
//! unrecoverable length prefix, answered first.

use std::io;
use std::net::{
    IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream,
};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;

use crate::apps;
use crate::coordinator::{EvalRequest, EvalService, EvalTicket};

use super::proto::{
    self, ErrorKind, Request, Response, SpecRef, WireEvalRequest,
};

/// One queued reply: either ready now (sync requests, protocol errors)
/// or a ticket the writer resolves in order.
enum Reply {
    Now(Response),
    Ticket(EvalTicket),
}

/// Releases one connection slot on drop — including when the
/// connection handler panics, so a fault can never leak capacity.
struct ConnSlot(Arc<AtomicUsize>);

impl Drop for ConnSlot {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Per-request budget on the simulated task graph a remote scenario may
/// ask for: `apps::scenario`'s per-parameter bounds keep the arithmetic
/// sane, but a product of in-range extents can still describe a graph
/// whose materialization would exhaust memory — and an allocation
/// failure *aborts* the shared server (it does not unwind into the
/// worker-panic containment).  Oversized scenarios classify as bad
/// requests instead.
const MAX_REQUEST_POINT_TASKS: i64 = 1 << 24;

/// Registered machine specs are deduplicated by fingerprint but the
/// registry itself is append-only (ids must stay stable), so remote
/// registration is capped — the one piece of service state a client
/// could otherwise grow without bound.
const MAX_REGISTERED_SPECS: usize = 1024;

/// Registry entries live forever and their names are re-cloned by every
/// summary/stats request, so a registered name (the alias *and* the
/// name embedded in the spec) may not exceed this — otherwise the entry
/// cap above still admits gigabytes of hostile name bytes.
const MAX_SPEC_NAME_BYTES: usize = 256;

/// Each connection costs two OS threads (reader + writer) and a cloned
/// socket; beyond this many concurrent connections the server answers a
/// classified capacity error and closes instead of exhausting
/// threads/fds under a reconnect storm.
const MAX_CONNECTIONS: usize = 256;

/// A TCP front over one shared [`EvalService`] (see module docs).
/// Binding spawns the accept loop; [`EvalServer::join`] blocks for a
/// serve-forever process, dropping (or [`EvalServer::shutdown`]) stops
/// accepting and joins the acceptor.  Established connections run to
/// client disconnect.
pub struct EvalServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
}

impl EvalServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral test port)
    /// and start accepting; every connection is served against
    /// `service`.
    pub fn bind(addr: &str, service: Arc<EvalService>) -> io::Result<EvalServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let conns = Arc::new(AtomicUsize::new(0));
        let accept = thread::Builder::new()
            .name("evalsrv-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop_flag.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(mut stream) => {
                            if conns.load(Ordering::SeqCst) >= MAX_CONNECTIONS {
                                // classified refusal, then close
                                let resp = Response::Error {
                                    kind: ErrorKind::Internal,
                                    msg: format!(
                                        "server at connection capacity \
                                         ({MAX_CONNECTIONS})"
                                    ),
                                };
                                let _ = proto::write_frame(&mut stream, &resp.encode());
                                continue;
                            }
                            conns.fetch_add(1, Ordering::SeqCst);
                            let service = Arc::clone(&service);
                            let slot = ConnSlot(Arc::clone(&conns));
                            // on spawn failure the closure (stream +
                            // guard) is dropped, and the guard's Drop
                            // releases the reservation either way
                            let _ = thread::Builder::new()
                                .name("evalsrv-conn".into())
                                .spawn(move || {
                                    // held for the connection's life:
                                    // released on return *and* on panic
                                    let _slot = slot;
                                    handle_conn(stream, service);
                                });
                        }
                        // transient accept errors (EMFILE, aborted
                        // handshakes) must not kill the server — but
                        // back off so a persistent error (fd
                        // exhaustion) cannot busy-spin this thread
                        Err(_) => {
                            thread::sleep(std::time::Duration::from_millis(50));
                            continue;
                        }
                    }
                }
            })?;
        Ok(EvalServer { addr: local, stop, accept: Some(accept) })
    }

    /// The bound address (resolves the ephemeral port of `":0"` binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the accept loop exits (the serve-forever CLI path).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting new connections and join the acceptor.
    pub fn shutdown(mut self) {
        self.stop_accepting();
    }

    fn stop_accepting(&mut self) {
        if let Some(h) = self.accept.take() {
            self.stop.store(true, Ordering::SeqCst);
            // unblock the blocking accept with a throwaway connection;
            // a wildcard bind (0.0.0.0 / ::) is not connectable on
            // every platform, so aim the wake-up at loopback instead
            let mut target = self.addr;
            if target.ip().is_unspecified() {
                let loopback = match target.ip() {
                    IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                    IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
                };
                target.set_ip(loopback);
            }
            let _ = TcpStream::connect(target);
            let _ = h.join();
        }
    }
}

impl Drop for EvalServer {
    fn drop(&mut self) {
        self.stop_accepting();
    }
}

/// Per-connection reader: decode frames, resolve or enqueue, preserve
/// order through the writer channel.
fn handle_conn(stream: TcpStream, service: Arc<EvalService>) {
    let _ = stream.set_nodelay(true);
    let mut reader = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (tx, rx) = mpsc::channel::<Reply>();
    let writer = thread::Builder::new()
        .name("evalsrv-write".into())
        .spawn(move || {
            let mut out = stream;
            for reply in rx {
                let resp = match reply {
                    Reply::Now(r) => r,
                    // worker panics surface through the ticket as
                    // classified execution-error feedback
                    Reply::Ticket(t) => Response::Feedback(t.wait()),
                };
                if proto::write_frame(&mut out, &resp.encode()).is_err() {
                    // client gone: remaining queued replies are simply
                    // dropped — pending evaluations still complete on
                    // the service's workers, their tickets just have no
                    // reader anymore
                    break;
                }
            }
            let _ = out.shutdown(Shutdown::Both);
        });
    let Ok(writer) = writer else { return };

    loop {
        let payload = match proto::read_frame(&mut reader) {
            Ok(Some(p)) => p,
            Ok(None) => break, // clean close
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                // unrecoverable framing: classify, answer, close
                let _ = tx.send(Reply::Now(Response::Error {
                    kind: ErrorKind::Frame,
                    msg: e.to_string(),
                }));
                break;
            }
            Err(_) => break, // transport failure
        };
        let reply = match Request::decode(&payload) {
            Ok(req) => serve_request(req, &service),
            // version skew / undecodable payloads answer in place; the
            // length prefix already resynchronized the stream
            Err(e) => Reply::Now(Response::Error {
                kind: e.wire_kind(),
                msg: e.to_string(),
            }),
        };
        if tx.send(reply).is_err() {
            break;
        }
    }
    drop(tx);
    let _ = writer.join();
}

fn bad_request(msg: String) -> Reply {
    Reply::Now(Response::Error { kind: ErrorKind::BadRequest, msg })
}

fn serve_request(req: Request, service: &Arc<EvalService>) -> Reply {
    match req {
        Request::Ping => Reply::Now(Response::Pong),
        Request::Eval(q) => match prepare_eval(q, service) {
            Ok(req) => Reply::Ticket(service.submit(req)),
            Err(reply) => reply,
        },
        Request::RegisterSpec { name, spec } => {
            if name.len() > MAX_SPEC_NAME_BYTES
                || spec.name.len() > MAX_SPEC_NAME_BYTES
            {
                bad_request(format!(
                    "spec names are limited to {MAX_SPEC_NAME_BYTES} bytes"
                ))
            } else {
                // capped atomically under the registry lock, so racing
                // registrations cannot overshoot the bound
                match service.registry().register_bounded(
                    &name,
                    spec,
                    MAX_REGISTERED_SPECS,
                ) {
                    Some(id) => Reply::Now(spec_info(service, id)),
                    None => bad_request(format!(
                        "spec registry is full ({MAX_REGISTERED_SPECS} entries); \
                         reuse a registered spec"
                    )),
                }
            }
        }
        Request::GetSpec { name } => match service.spec_id(&name) {
            Some(id) => Reply::Now(spec_info(service, id)),
            None => bad_request(format!("unknown machine spec '{name}'")),
        },
        Request::Stats => Reply::Now(Response::Stats(service.snapshot())),
        Request::Summary => Reply::Now(Response::Summary(service.summary())),
    }
}

fn spec_info(service: &EvalService, id: crate::coordinator::SpecId) -> Response {
    Response::SpecInfo {
        id: id.index() as u32,
        name: service.registry().name(id),
        spec: service.spec(id),
    }
}

/// Resolve the wire request into a service request: spec ref against
/// the registry, scenario into a concrete [`App`](crate::apps::App).
fn prepare_eval(
    q: WireEvalRequest,
    service: &Arc<EvalService>,
) -> Result<EvalRequest, Reply> {
    let spec_id = match &q.spec {
        SpecRef::Id(i) => service
            .registry()
            .by_index(*i as usize)
            .ok_or_else(|| bad_request(format!("unknown machine spec id {i}")))?,
        SpecRef::Name(n) => service
            .spec_id(n)
            .ok_or_else(|| bad_request(format!("unknown machine spec '{n}'")))?,
    };
    let app = apps::scenario(&q.scenario.app, &q.scenario.params)
        .map_err(bad_request)?;
    // budget the graph before any engine materializes it, summing every
    // step's launches — launch structure can vary per step (Solomonik
    // adds its reduce launch only on the last one), so pricing step 0
    // alone would undercount; the early break keeps this loop itself
    // budget-bounded for huge step counts
    let mut total: i64 = 0;
    for step in 0..app.steps {
        let per_step: i64 = app.launches(step).iter().map(|l| l.num_points()).sum();
        total = total.saturating_add(per_step);
        if total > MAX_REQUEST_POINT_TASKS {
            return Err(bad_request(format!(
                "scenario '{}' describes over {total} point tasks, over the \
                 per-request budget of {MAX_REQUEST_POINT_TASKS}",
                q.scenario.app
            )));
        }
    }
    Ok(EvalRequest {
        spec_id,
        app: Arc::new(app),
        dsl: q.dsl,
        mode: q.mode,
        priority: q.priority,
    })
}
