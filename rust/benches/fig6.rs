//! Bench: regenerate paper Figure 6 at full parameters (10 iters x 5 runs,
//! 10 random mappers) and report the wall-clock of the whole campaign.
use mapperopt::coordinator::Coordinator;
use mapperopt::harness::{fig6, ExpParams};
use mapperopt::machine::MachineSpec;
use mapperopt::util::benchkit::time_once;

fn main() {
    let coord = Coordinator::new(MachineSpec::p100_cluster());
    let results = time_once("fig6 (3 apps x (trace+opro) x 5 runs x 10 iters)", || {
        fig6(&coord, ExpParams::default())
    });
    for r in &results {
        println!(
            "  {:8} expert=1.00 random={:.2} trace-best={:.2}",
            r.bench, r.random_norm, r.trace_best_norm
        );
    }
}
