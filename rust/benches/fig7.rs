//! Bench: regenerate paper Figure 7 at full parameters.
use mapperopt::coordinator::Coordinator;
use mapperopt::harness::{fig7, ExpParams};
use mapperopt::machine::MachineSpec;
use mapperopt::util::benchkit::time_once;

fn main() {
    let coord = Coordinator::new(MachineSpec::p100_cluster());
    let results = time_once("fig7 (6 algos x (trace+opro) x 5 runs x 10 iters)", || {
        fig7(&coord, ExpParams::default())
    });
    for r in &results {
        println!(
            "  {:10} expert=1.00 random={:.2} trace-best={:.2}",
            r.bench, r.random_norm, r.trace_best_norm
        );
    }
}
