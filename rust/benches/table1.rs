//! Bench: regenerate paper Table 1 (mapper LoC) and time the DSL compiler
//! over all nine expert mappers.
use mapperopt::dsl::MappingPolicy;
use mapperopt::harness;
use mapperopt::machine::MachineSpec;
use mapperopt::mapping::all_experts;
use mapperopt::util::benchkit::{bench, time_once};

fn main() {
    time_once("table1 (full regeneration)", harness::table1);
    let spec = MachineSpec::p100_cluster();
    bench("compile all 9 expert mappers", 50, || {
        for (_, dsl) in all_experts() {
            std::hint::black_box(MappingPolicy::compile(dsl, &spec).unwrap());
        }
    });
}
