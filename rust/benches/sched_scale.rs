//! Scheduler scalability: end-to-end evaluation throughput on the
//! `stencil3d` halo-exchange app across task-graph sizes, for all three
//! execution engines.
//!
//! Reports ms/eval, point-tasks/sec, and evals/sec per (size, engine),
//! plus the coordinator-level throughput counters — the numbers a
//! many-campaign optimization service lives and dies by.
//!
//! Run small-only (CI smoke): `cargo bench --bench sched_scale -- smoke`

use std::time::Instant;

use mapperopt::apps::{self, App, Stencil3dConfig};
use mapperopt::coordinator::Coordinator;
use mapperopt::machine::MachineSpec;
use mapperopt::mapping::expert_dsl;
use mapperopt::sim::{run_mapper_with, ExecMode};

fn measure(
    app: &App,
    tasks: usize,
    dsl: &str,
    spec: &MachineSpec,
    mode: ExecMode,
    reps: usize,
) {
    // warmup (also validates the run)
    run_mapper_with(app, dsl, spec, mode).unwrap().unwrap();
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(run_mapper_with(app, dsl, spec, mode).unwrap().unwrap());
    }
    let dt = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "sched_scale {:>6} tasks  {:12} {:>10.2} ms/eval  {:>12.0} tasks/s  {:>8.2} evals/s",
        tasks,
        mode.name(),
        dt * 1e3,
        tasks as f64 / dt,
        1.0 / dt
    );
}

fn main() {
    let smoke = std::env::args().any(|a| a == "smoke" || a == "--smoke");
    let spec = MachineSpec::p100_cluster();
    let dsl = expert_dsl("stencil3d").unwrap();

    let sizes: &[usize] =
        if smoke { &[1_000] } else { &[1_000, 10_000, 50_000, 100_000] };
    for &n in sizes {
        let cfg = Stencil3dConfig::with_min_point_tasks(n);
        let tasks = cfg.point_tasks();
        let app = apps::stencil3d(cfg);
        let reps = if tasks <= 2_000 { 5 } else { 2 };
        for mode in [ExecMode::BulkSync, ExecMode::Serialized, ExecMode::OutOfOrder] {
            measure(&app, tasks, dsl, &spec, mode, reps);
        }
    }

    // coordinator-level throughput: three distinct mappers on a 10^4-task
    // graph (comment suffixes defeat the content cache without changing
    // mapping semantics)
    let coord = Coordinator::new(spec);
    let app = apps::stencil3d(Stencil3dConfig::with_min_point_tasks(
        if smoke { 1_000 } else { 10_000 },
    ));
    for i in 0..3 {
        let variant = format!("{dsl}# variant {i}\n");
        std::hint::black_box(coord.evaluate(&app, &variant));
    }
    println!(
        "coordinator  {:>6.2} evals/s  {:>12.0} point-tasks/s",
        coord.stats().evals_per_sec(),
        coord.stats().point_tasks_per_sec()
    );
}
