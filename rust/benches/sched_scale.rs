//! Scheduler scalability: end-to-end evaluation throughput on the
//! `stencil3d` halo-exchange app across task-graph sizes, for all three
//! execution engines — plus the campaign benchmark: repeated
//! evaluations of *distinct* mappers on one app, cold (fresh DSL
//! compile + DAG build + buffers per eval) vs warm (`EvalService` with
//! its plan / policy / decision caches and per-worker `SimArena`), and
//! a semantic-alias phase (reformatted mappers, identical decisions)
//! that measures the decision cache.
//!
//! Flags (combine freely):
//!   smoke — CI sizes only
//!   json  — print ONLY a machine-readable JSON line with the campaign
//!           evals/sec + point-tasks/sec numbers (the `BENCH_*.json`
//!           seed; see `make bench-json`)
//!
//! Run small-only (CI smoke): `cargo bench --bench sched_scale -- smoke`

use std::time::Instant;

use mapperopt::apps::{self, App, Stencil3dConfig};
use mapperopt::coordinator::{Coordinator, EvalService};
use mapperopt::machine::MachineSpec;
use mapperopt::mapping::expert_dsl;
use mapperopt::sim::{run_mapper_with, ExecMode};

fn measure(
    app: &App,
    tasks: usize,
    dsl: &str,
    spec: &MachineSpec,
    mode: ExecMode,
    reps: usize,
) {
    // warmup (also validates the run)
    run_mapper_with(app, dsl, spec, mode).unwrap().unwrap();
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(run_mapper_with(app, dsl, spec, mode).unwrap().unwrap());
    }
    let dt = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "sched_scale {:>6} tasks  {:12} {:>10.2} ms/eval  {:>12.0} tasks/s  {:>8.2} evals/s",
        tasks,
        mode.name(),
        dt * 1e3,
        tasks as f64 / dt,
        1.0 / dt
    );
}

/// Mappers with pairwise-distinct concrete decision vectors: every
/// (multiplier % 4, offset % 4) pair induces a different per-point GPU
/// assignment on the 2x4 cluster, so the decision cache cannot alias
/// them — each one costs a real simulation.
fn distinct_mappers(k: usize) -> Vec<String> {
    assert!(k <= 12, "only 12 guaranteed-distinct (m, c) pairs generated");
    (0..k)
        .map(|i| {
            let m = 1 + i / 4; // 1..=3
            let c = i % 4;
            format!(
                "Task * GPU;\n\
                 Region * * GPU FBMEM;\n\
                 Layout * * * SOA C_order Align==64;\n\
                 mgpu = Machine(GPU);\n\
                 def v{i}(Tuple ipoint, Tuple ispace) {{\n\
                 \x20 lin = ipoint[0] * {m} + {c};\n\
                 \x20 return mgpu[lin % mgpu.size[0], lin % mgpu.size[1]];\n\
                 }}\n\
                 IndexTaskMap * v{i};\n"
            )
        })
        .collect()
}

struct CampaignNumbers {
    tasks: usize,
    mappers: usize,
    cold_eps: f64,
    warm_eps: f64,
    alias_eps: f64,
    cold_tps: f64,
    warm_tps: f64,
    decision_hits: usize,
}

/// The campaign hot path: K distinct mappers on one >= `min_tasks`-task
/// app, cold vs warm, then K semantic aliases of the same mappers.
fn campaign(min_tasks: usize) -> CampaignNumbers {
    let cfg = Stencil3dConfig::with_min_point_tasks(min_tasks);
    let tasks = cfg.point_tasks();
    let app = apps::stencil3d(cfg);
    let spec = MachineSpec::p100_cluster();
    let mappers = distinct_mappers(12);

    // cold: the full per-eval pipeline — DSL compile, launch flattening,
    // DAG build, fresh scratch buffers — per mapper
    let t0 = Instant::now();
    for dsl in &mappers {
        std::hint::black_box(
            run_mapper_with(&app, dsl, &spec, ExecMode::Serialized).unwrap().unwrap(),
        );
    }
    let cold_s = t0.elapsed().as_secs_f64();

    // warm: the serving path — shared EvalPlan, policy cache, reusable
    // per-thread SimArena; every mapper still simulates (decisions are
    // pairwise distinct)
    let service = EvalService::new(1, 8);
    let sid = service.spec_id("p100_cluster").unwrap();
    let t1 = Instant::now();
    for dsl in &mappers {
        std::hint::black_box(service.evaluate(sid, &app, dsl, ExecMode::Serialized));
    }
    let warm_s = t1.elapsed().as_secs_f64();

    // aliases: textually new, semantically identical — the decision
    // cache serves them without re-simulating
    let t2 = Instant::now();
    for (i, dsl) in mappers.iter().enumerate() {
        let alias = format!("# llm rewrite {i}\n{dsl}# renamed candidate\n");
        std::hint::black_box(service.evaluate(sid, &app, &alias, ExecMode::Serialized));
    }
    let alias_s = t2.elapsed().as_secs_f64();

    let k = mappers.len() as f64;
    let stats = service.stats();
    CampaignNumbers {
        tasks,
        mappers: mappers.len(),
        cold_eps: k / cold_s,
        warm_eps: k / warm_s,
        alias_eps: k / alias_s,
        cold_tps: k * tasks as f64 / cold_s,
        warm_tps: k * tasks as f64 / warm_s,
        decision_hits: stats
            .decision_hits
            .load(std::sync::atomic::Ordering::Relaxed),
    }
}

impl CampaignNumbers {
    fn json(&self) -> String {
        format!(
            "{{\"bench\":\"sched_scale_campaign\",\"tasks\":{},\"mappers\":{},\
             \"cold_evals_per_sec\":{:.3},\"warm_evals_per_sec\":{:.3},\
             \"warm_over_cold\":{:.3},\"alias_evals_per_sec\":{:.3},\
             \"cold_point_tasks_per_sec\":{:.0},\"warm_point_tasks_per_sec\":{:.0},\
             \"decision_hits\":{}}}",
            self.tasks,
            self.mappers,
            self.cold_eps,
            self.warm_eps,
            self.warm_eps / self.cold_eps,
            self.alias_eps,
            self.cold_tps,
            self.warm_tps,
            self.decision_hits,
        )
    }

    fn human(&self) -> String {
        format!(
            "campaign {:>6} tasks x {} mappers: cold {:>7.2} evals/s  warm {:>7.2} \
             evals/s ({:.2}x)  aliases {:>8.2} evals/s ({} decision hits)\n\
             campaign point-tasks/s: cold {:>12.0}  warm {:>12.0}",
            self.tasks,
            self.mappers,
            self.cold_eps,
            self.warm_eps,
            self.warm_eps / self.cold_eps,
            self.alias_eps,
            self.decision_hits,
            self.cold_tps,
            self.warm_tps,
        )
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "smoke" || a == "--smoke");
    let json = args.iter().any(|a| a == "json" || a == "--json");
    let campaign_tasks = if smoke { 1_000 } else { 10_000 };

    if json {
        // machine-readable only: one JSON object on stdout
        println!("{}", campaign(campaign_tasks).json());
        return;
    }

    let spec = MachineSpec::p100_cluster();
    let dsl = expert_dsl("stencil3d").unwrap();

    let sizes: &[usize] =
        if smoke { &[1_000] } else { &[1_000, 10_000, 50_000, 100_000] };
    for &n in sizes {
        let cfg = Stencil3dConfig::with_min_point_tasks(n);
        let tasks = cfg.point_tasks();
        let app = apps::stencil3d(cfg);
        let reps = if tasks <= 2_000 { 5 } else { 2 };
        for mode in [ExecMode::BulkSync, ExecMode::Serialized, ExecMode::OutOfOrder] {
            measure(&app, tasks, dsl, &spec, mode, reps);
        }
    }

    // one campaign run serves both renderings (CI smoke covers the JSON
    // path without re-simulating)
    let numbers = campaign(campaign_tasks);
    println!("{}", numbers.human());
    println!("{}", numbers.json());

    // coordinator-level throughput: three distinct mappers on a 10^4-task
    // graph (comment suffixes defeat the text cache without changing
    // mapping semantics — since PR 4 they hit the decision cache instead,
    // so the counters below reflect one real simulation)
    let coord = Coordinator::new(spec);
    let app = apps::stencil3d(Stencil3dConfig::with_min_point_tasks(campaign_tasks));
    for i in 0..3 {
        let variant = format!("{dsl}# variant {i}\n");
        std::hint::black_box(coord.evaluate(&app, &variant));
    }
    println!(
        "coordinator  {:>6.2} evals/s  {:>12.0} point-tasks/s",
        coord.stats().evals_per_sec(),
        coord.stats().point_tasks_per_sec()
    );
}
