//! Delta-campaign benchmark: per-iteration cost of an optimizer step
//! that changes a handful of decisions, cold (full re-simulation of the
//! whole task graph per candidate) vs spliced (incremental
//! cone-of-influence re-simulation against the incumbent's
//! `ScheduleSnapshot` inside `EvalService`).
//!
//! The campaign mirrors the optimizer's hot loop: one base mapper is
//! evaluated (and recorded), then K candidates each retarget a single
//! launch point — the exact "small delta" shape LLM optimizer steps
//! produce — on the `stencil3d` app at growing task-graph sizes.
//!
//! Flags (combine freely):
//!   smoke — CI size only (1k tasks)
//!   json  — print ONLY one machine-readable JSON line (the
//!           `BENCH_delta.json` seed; see `make bench-json`)
//!
//! Run small-only (CI smoke): `cargo bench --bench delta_campaign -- smoke`

use std::time::Instant;

use mapperopt::apps::{self, Stencil3dConfig};
use mapperopt::coordinator::{CacheConfig, EvalService};
use mapperopt::machine::MachineSpec;
use mapperopt::sim::{run_mapper_with, ExecMode};

/// Base mapper: every launch point lands on `mgpu[lin % s0, lin % s1]`.
/// `py`/`pz` fold the 3-D launch point into the same linearization the
/// perturbations key on, so a retarget of `lin == t` moves exactly one
/// spatial tile.
fn base_mapper(py: i64, pz: i64) -> String {
    format!(
        "Task * GPU;\n\
         Region * * GPU FBMEM;\n\
         Layout * * * SOA C_order Align==64;\n\
         mgpu = Machine(GPU);\n\
         def send(Tuple ipoint, Tuple ispace) {{\n\
         \x20 lin = (ipoint[0] * {py} + ipoint[1]) * {pz} + ipoint[2];\n\
         \x20 return mgpu[lin % mgpu.size[0], lin % mgpu.size[1]];\n\
         }}\n\
         IndexTaskMap * send;\n"
    )
}

/// K single-tile perturbations of the base: candidate `i` reroutes the
/// point with `lin == 4i+1` to `mgpu[0, 0]` (the base maps odd `lin` to
/// node 1, so every retarget is a real decision change and every
/// candidate's decision vector is pairwise distinct).
fn perturbations(py: i64, pz: i64, k: usize) -> Vec<String> {
    (0..k)
        .map(|i| {
            let t = 4 * i + 1;
            format!(
                "Task * GPU;\n\
                 Region * * GPU FBMEM;\n\
                 Layout * * * SOA C_order Align==64;\n\
                 mgpu = Machine(GPU);\n\
                 def send(Tuple ipoint, Tuple ispace) {{\n\
                 \x20 lin = (ipoint[0] * {py} + ipoint[1]) * {pz} + ipoint[2];\n\
                 \x20 return lin == {t} ? mgpu[0, 0] : \
                 mgpu[lin % mgpu.size[0], lin % mgpu.size[1]];\n\
                 }}\n\
                 IndexTaskMap * send;\n"
            )
        })
        .collect()
}

struct DeltaNumbers {
    tasks: usize,
    candidates: usize,
    cold_ms: f64,
    spliced_ms: f64,
    delta_evals: u64,
    spliced_point_tasks: u64,
    dirty_fallbacks: u64,
}

impl DeltaNumbers {
    fn speedup(&self) -> f64 {
        self.cold_ms / self.spliced_ms
    }

    fn json(&self) -> String {
        format!(
            "{{\"tasks\":{},\"candidates\":{},\"cold_ms_per_eval\":{:.4},\
             \"spliced_ms_per_eval\":{:.4},\"speedup\":{:.2},\
             \"delta_evals\":{},\"spliced_point_tasks\":{},\
             \"dirty_fallbacks\":{}}}",
            self.tasks,
            self.candidates,
            self.cold_ms,
            self.spliced_ms,
            self.speedup(),
            self.delta_evals,
            self.spliced_point_tasks,
            self.dirty_fallbacks,
        )
    }

    fn human(&self) -> String {
        format!(
            "delta_campaign {:>7} tasks x {} candidates: cold {:>9.3} ms/eval  \
             spliced {:>9.3} ms/eval  ({:>6.2}x)  \
             [{} spliced, {} fallbacks, {} point tasks replayed]",
            self.tasks,
            self.candidates,
            self.cold_ms,
            self.spliced_ms,
            self.speedup(),
            self.delta_evals,
            self.dirty_fallbacks,
            self.spliced_point_tasks,
        )
    }
}

/// One campaign at >= `min_tasks` point tasks: base + K one-tile
/// candidates, cold loop vs serving loop with splicing enabled.
fn campaign(min_tasks: usize) -> DeltaNumbers {
    const K: usize = 8;
    let cfg = Stencil3dConfig::with_min_point_tasks(min_tasks);
    let tasks = cfg.point_tasks();
    let (py, pz) = (cfg.py, cfg.pz);
    let app = apps::stencil3d(cfg);
    let spec = MachineSpec::p100_cluster();
    let base = base_mapper(py, pz);
    let cands = perturbations(py, pz, K);

    // cold: every candidate pays a full simulation (plus compile + DAG
    // build — the per-eval pipeline an optimizer without a serving
    // layer runs); base first as warmup + validation
    run_mapper_with(&app, &base, &spec, ExecMode::Serialized).unwrap().unwrap();
    let t0 = Instant::now();
    for dsl in &cands {
        std::hint::black_box(
            run_mapper_with(&app, dsl, &spec, ExecMode::Serialized).unwrap().unwrap(),
        );
    }
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3 / K as f64;

    // spliced: the base eval records the incumbent snapshot, then each
    // candidate re-simulates only its dirty cone.  The one-tile cone is
    // ~33% of the DAG at the 1k smoke size, so the threshold is raised
    // from the 0.25 default to splice uniformly across sizes.
    let service = EvalService::with_cache_config(
        1,
        K.max(2),
        CacheConfig { delta_dirty_frac: 0.5, ..CacheConfig::default() },
    );
    let sid = service.spec_id("p100_cluster").unwrap();
    std::hint::black_box(service.evaluate(sid, &app, &base, ExecMode::Serialized));
    let t1 = Instant::now();
    for dsl in &cands {
        std::hint::black_box(service.evaluate(sid, &app, dsl, ExecMode::Serialized));
    }
    let spliced_ms = t1.elapsed().as_secs_f64() * 1e3 / K as f64;

    let snap = service.snapshot();
    DeltaNumbers {
        tasks,
        candidates: K,
        cold_ms,
        spliced_ms,
        delta_evals: snap.delta_evals,
        spliced_point_tasks: snap.spliced_point_tasks,
        dirty_fallbacks: snap.dirty_fallbacks,
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "smoke" || a == "--smoke");
    let json = args.iter().any(|a| a == "json" || a == "--json");
    let sizes: &[usize] = if smoke { &[1_000] } else { &[1_000, 10_000, 100_000] };

    let runs: Vec<DeltaNumbers> = sizes.iter().map(|&n| campaign(n)).collect();

    if json {
        // machine-readable only: one JSON object on stdout
        let sizes_json: Vec<String> = runs.iter().map(|r| r.json()).collect();
        println!(
            "{{\"bench\":\"delta_campaign\",\"sizes\":[{}]}}",
            sizes_json.join(",")
        );
        return;
    }

    for r in &runs {
        println!("{}", r.human());
        // splice counters double as a correctness canary: a candidate
        // that reaches neither counter never took the delta path (no
        // incumbent snapshot — e.g. the base ran under eviction
        // pressure), and the spliced column is really a cold measurement
        if r.delta_evals + r.dirty_fallbacks != r.candidates as u64 {
            println!(
                "delta_campaign WARNING: {}/{} candidates bypassed the delta path",
                r.candidates as u64 - (r.delta_evals + r.dirty_fallbacks),
                r.candidates
            );
        }
    }
}
