//! Bench: regenerate paper Figure 8 (feedback ablation) at full parameters.
use mapperopt::coordinator::Coordinator;
use mapperopt::harness::{fig8, ExpParams};
use mapperopt::machine::MachineSpec;
use mapperopt::util::benchkit::time_once;

fn main() {
    let coord = Coordinator::new(MachineSpec::p100_cluster());
    let results = time_once("fig8 (3 benches x 3 configs x 5 runs x 10 iters)", || {
        fig8(&coord, ExpParams::default())
    });
    for r in &results {
        println!("  {:8} {:24} final={:.2}", r.bench, r.config, r.final_norm);
    }
}
