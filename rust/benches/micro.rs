//! Microbenchmarks over the hot paths the §Perf pass optimizes:
//! DSL lexing/parsing/compilation, mapping-function evaluation,
//! processor-space resolution, the simulator's end-to-end step loop,
//! agent rendering, and the coordinator's cached evaluation path.
use mapperopt::apps;
use mapperopt::coordinator::Coordinator;
use mapperopt::dsl::{self, MappingPolicy, TaskCtx};
use mapperopt::machine::{MachineSpec, ProcKind, ProcSpace};
use mapperopt::mapping::expert_dsl;
use mapperopt::optimizer::{AgentGenome, AppInfo};
use mapperopt::sim::Executor;
use mapperopt::util::benchkit::bench;
use mapperopt::util::rng::Rng;

fn main() {
    let spec = MachineSpec::p100_cluster();
    let circuit_dsl = expert_dsl("circuit").unwrap();
    let cannon_dsl = expert_dsl("cannon").unwrap();

    bench("dsl::parse (circuit expert)", 2000, || {
        std::hint::black_box(dsl::parse(circuit_dsl).unwrap());
    });
    bench("dsl::compile (circuit expert)", 2000, || {
        std::hint::black_box(MappingPolicy::compile(circuit_dsl, &spec).unwrap());
    });

    let policy = MappingPolicy::compile(cannon_dsl, &spec).unwrap();
    let ctx = TaskCtx { ipoint: vec![2, 3], ispace: vec![4, 4], parent_proc: None };
    bench("policy::select_processor (map func eval)", 5000, || {
        std::hint::black_box(
            policy
                .select_processor("dgemm", &ctx, &[ProcKind::Gpu], &spec)
                .unwrap(),
        );
    });

    let space = ProcSpace::machine(&spec, ProcKind::Gpu)
        .split(1, 2)
        .unwrap()
        .merge(0, 1)
        .unwrap();
    bench("procspace::resolve (split+merge chain)", 5000, || {
        std::hint::black_box(space.resolve(&[3, 1]).unwrap());
    });

    let app = apps::by_name("circuit").unwrap();
    let cpolicy = MappingPolicy::compile(circuit_dsl, &spec).unwrap();
    let ex = Executor::new(&spec);
    bench("sim::execute (circuit, 10 steps)", 200, || {
        std::hint::black_box(ex.execute(&app, &cpolicy).unwrap());
    });
    let mm = apps::by_name("cannon").unwrap();
    let mpolicy = MappingPolicy::compile(cannon_dsl, &spec).unwrap();
    bench("sim::execute (cannon, 4 steps)", 200, || {
        std::hint::black_box(ex.execute(&mm, &mpolicy).unwrap());
    });

    let info = AppInfo::from_app(&app);
    let genome = AgentGenome::random(&info, &mut Rng::new(1));
    bench("agent::render", 5000, || {
        std::hint::black_box(genome.render());
    });

    let coord = Coordinator::new(spec.clone());
    bench("coordinator::evaluate (cache hit path)", 2000, || {
        std::hint::black_box(coord.evaluate(&app, circuit_dsl));
    });
}
