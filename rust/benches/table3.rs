//! Bench: regenerate paper Table 3 (strategy -> code generation) and time
//! the generation + compile + check pipeline.
use mapperopt::harness::strategies::{generate_dsl, judge_dsl, strategies, table3};
use mapperopt::machine::MachineSpec;
use mapperopt::util::benchkit::{bench, time_once};

fn main() {
    let spec = MachineSpec::p100_cluster();
    time_once("table3 (full regeneration)", || table3(&spec));
    let strats = strategies();
    bench("generate+compile+check all 10 strategies", 50, || {
        for s in &strats {
            let src = generate_dsl(s);
            std::hint::black_box(judge_dsl(s, &src, &spec));
        }
    });
}
