# mapperopt — build / test / experiment entry points.
#
#   make verify     tier-1: release build + full test suite
#   make artifacts  AOT-lower the python task bodies to artifacts/*.hlo.txt
#                   (needed only for the PJRT runtime path; tests skip
#                   cleanly when artifacts/ is absent)
#   make ci         what .github/workflows/ci.yml runs

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test verify fmt fmt-check clippy ci artifacts figures clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

verify: build test

fmt:
	$(CARGO) fmt --all

fmt-check:
	$(CARGO) fmt --all -- --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

ci: fmt-check clippy verify

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

figures:
	$(CARGO) run --release -- all

clean:
	$(CARGO) clean
	rm -rf results
