# mapperopt — build / test / experiment entry points.
#
#   make verify      tier-1: release build + full test suite
#   make test-props  the property suites at raised case counts
#                    (PROPTEST_CASES, exported as MAPPEROPT_PROPTEST_CASES;
#                    tier-1 keeps the small in-code defaults)
#   make bench-smoke build every bench target and run the scheduler
#                    scalability + delta-splice benches at their smallest
#                    sizes (CI keeps bench code from rotting); the
#                    campaign sections print their JSON lines alongside
#                    the human ones
#   make bench-json  run the warm-vs-cold campaign benchmark, the
#                    cold-vs-spliced delta campaign, and the serving
#                    loadtest at full scale, writing the numbers as JSON
#                    to BENCH_sched_scale.json, BENCH_delta.json, and
#                    BENCH_serve.json (the machine-readable trajectory
#                    seeds)
#   make loadtest-smoke
#                    boot the multiplexed eval server in-process and
#                    sustain a few hundred concurrent synthetic clients
#                    for a short window — sized to fit a default 1024-fd
#                    ulimit, health-gated on zero unclassified errors
#                    (the full 1000+-client run lives in bench-json,
#                    which raises the fd limit)
#   make fleet-smoke boot 2 in-process eval shards behind the
#                    cache-affinity router and sustain 200 synthetic
#                    clients through the front for a short window,
#                    health-gated like loadtest-smoke (the full
#                    {1,2,4}-shard scaling sweep lives in bench-json
#                    as BENCH_fleet.json)
#   make serve-smoke boot the TCP eval server on loopback, run two
#                    concurrent remote campaigns against it, and assert
#                    remote == in-process bit-identically (the example
#                    self-enforces a deadline so CI can never hang)
#   make chaos-smoke run a remote campaign through the seeded chaos
#                    proxy (delays, corruption, truncation, resets) and
#                    assert it is bit-identical to a clean local run
#                    with retries and reconnects actually exercised
#                    (on failure the server's flight recorder is dumped)
#   make trace-smoke run a traced remote campaign through a 2-shard
#                    routed fleet and assert tracing is inert
#                    (bit-identical to untraced) with a flight-recorder
#                    span covering every traced evaluation
#   make artifacts   AOT-lower the python task bodies to artifacts/*.hlo.txt
#                    (needed only for the PJRT runtime path; tests skip
#                    cleanly when artifacts/ is absent)
#   make ci          what .github/workflows/ci.yml runs

CARGO ?= cargo
PYTHON ?= python3
PROPTEST_CASES ?= 400

.PHONY: build test verify test-props bench-smoke bench-json serve-smoke chaos-smoke loadtest-smoke fleet-smoke trace-smoke fmt fmt-check clippy ci artifacts figures clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

verify: build test

test-props:
	MAPPEROPT_PROPTEST_CASES=$(PROPTEST_CASES) $(CARGO) test -q --release --test property_suite

bench-smoke:
	$(CARGO) build --benches
	$(CARGO) bench --bench sched_scale -- smoke
	$(CARGO) bench --bench delta_campaign -- smoke

bench-json:
	$(CARGO) build --benches
	$(CARGO) bench --bench sched_scale -- json | tee BENCH_sched_scale.json
	$(CARGO) bench --bench delta_campaign -- json | tee BENCH_delta.json
	ulimit -n 8192 2>/dev/null; MAPPEROPT_SERVE_DEADLINE_S=300 \
		$(CARGO) run --release -- loadtest --clients 1000 --duration 8 --json \
		| tee BENCH_serve.json
	ulimit -n 8192 2>/dev/null; MAPPEROPT_SERVE_DEADLINE_S=420 \
		$(CARGO) run --release -- loadtest --router --shards 1,2,4 \
		--clients 1000 --duration 8 --json | tee BENCH_fleet.json

serve-smoke:
	MAPPEROPT_SERVE_DEADLINE_S=300 $(CARGO) run --release --example e2e_remote

chaos-smoke:
	MAPPEROPT_SERVE_DEADLINE_S=300 $(CARGO) run --release -- chaos-smoke

loadtest-smoke:
	MAPPEROPT_SERVE_DEADLINE_S=300 $(CARGO) run --release -- loadtest \
		--clients 200 --duration 3

fleet-smoke:
	MAPPEROPT_SERVE_DEADLINE_S=300 $(CARGO) run --release -- loadtest \
		--router --shards 2 --clients 200 --duration 3

trace-smoke:
	MAPPEROPT_SERVE_DEADLINE_S=300 $(CARGO) run --release -- trace-smoke

fmt:
	$(CARGO) fmt --all

fmt-check:
	$(CARGO) fmt --all -- --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

ci: fmt-check clippy verify test-props

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

figures:
	$(CARGO) run --release -- all

clean:
	$(CARGO) clean
	rm -rf results
